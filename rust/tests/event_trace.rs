//! Golden event-sequence suite: the determinism contract extended to the
//! asynchronous tier engine's event stream itself.
//!
//! An async DTFL session is recorded from the single-thread sequential
//! reference as a stream of [`EventRecord`] rows — event kind, client,
//! tier, virtual timestamp bits, staleness-weight bits, and an FNV-1a
//! parameter checksum at each flush/broadcast — plus the per-window round
//! records and the final global parameter bits. Every engine configuration
//! in the `{threads, intra_threads, pipeline_depth, agg_shards,
//! fuse_forward, simd}` grid must reproduce all three **byte for byte**
//! (the CI determinism matrix injects extra legs via `DTFL_TEST_THREADS`
//! and `DTFL_TEST_SIMD`, exactly like `tests/golden_trace.rs`).
//!
//! On top of the byte contract, the suite pins the async engine's
//! semantics on crafted scenarios: the committed straggler-heavy trace
//! must be strictly faster end to end than both synchronous deadline
//! policies at no loss cost; a tier whose every client churns out
//! carries the model forward through empty flushes; quarantined
//! non-finite updates never reach a cross-tier merge; and a flaky
//! uplink's retry backoff is charged exactly once in virtual time even
//! when the attempt spans tier-flush boundaries.

use dtfl::coordinator::UplinkCodec;
use dtfl::experiment::Experiment;
use dtfl::harness::{self, RunSpec, STRAGGLER_HEAVY_TOML};
use dtfl::metrics::RoundRecord;
use dtfl::runtime::{simd, SimdLevel};
use dtfl::simulation::{CohortSpec, CorruptMode, DeadlinePolicy, EventKind, EventRecord, Scenario};

/// One async window row, everything reduced to exact bit patterns.
#[derive(Debug, Clone, PartialEq, Eq)]
struct WindowRow {
    round: usize,
    sim_time: u64,
    train_loss: u64,
    test_loss: Option<u64>,
    staleness: u64,
    tier_flushes: usize,
    straggled: usize,
    quarantined: usize,
    retries: usize,
    wire_bytes: u64,
    /// Post-codec uplink bytes per async window (knob-invariant).
    up_wire_bytes: u64,
}

/// One async session's full golden trace: the event stream, the window
/// rows, and the final global parameter bits.
#[derive(Debug, Clone, PartialEq, Eq)]
struct AsyncTrace {
    events: Vec<EventRecord>,
    windows: Vec<WindowRow>,
    params: Vec<u32>,
}

fn window_rows(records: &[RoundRecord]) -> Vec<WindowRow> {
    records
        .iter()
        .map(|r| WindowRow {
            round: r.round,
            sim_time: r.sim_time.to_bits(),
            train_loss: r.train_loss.to_bits(),
            test_loss: r.test_loss.map(f64::to_bits),
            staleness: r.staleness.to_bits(),
            tier_flushes: r.tier_flushes,
            straggled: r.straggled,
            quarantined: r.quarantined,
            retries: r.retries,
            wire_bytes: r.wire_bytes,
            up_wire_bytes: r.up_wire_bytes,
        })
        .collect()
}

/// Engine configuration under test (`simd: None` = `[run] simd = "auto"`).
#[derive(Debug, Clone, Copy)]
struct Knobs {
    threads: usize,
    intra: usize,
    depth: usize,
    shards: usize,
    fuse: bool,
    simd: Option<SimdLevel>,
}

const REFERENCE: Knobs = Knobs {
    threads: 1,
    intra: 1,
    depth: 1,
    shards: 1,
    fuse: false,
    simd: Some(SimdLevel::Scalar),
};

/// Run one async DTFL session and capture its full golden trace.
fn run_async(
    scenario: Option<Scenario>,
    clients: usize,
    rounds: usize,
    eval_every: usize,
    k: Knobs,
) -> AsyncTrace {
    run_async_with_uplink(scenario, clients, rounds, eval_every, k, env_uplink())
}

fn run_async_with_uplink(
    scenario: Option<Scenario>,
    clients: usize,
    rounds: usize,
    eval_every: usize,
    k: Knobs,
    uplink: UplinkCodec,
) -> AsyncTrace {
    let spec = RunSpec {
        method: "dtfl".into(),
        clients,
        rounds,
        batch_cap: Some(1),
        train_total: clients * 16,
        test_total: 32,
        eval_every,
        threads: k.threads,
        intra_threads: k.intra,
        pipeline_depth: k.depth,
        agg_shards: k.shards,
        fuse_forward: k.fuse,
        simd: k.simd.map_or_else(|| "auto".into(), |l| l.name().into()),
        uplink,
        async_tiers: true,
        scenario,
        ..Default::default()
    };
    let mut exp = Experiment::new(spec.to_config()).expect("async experiment");
    let mut records = Vec::new();
    exp.run_with(|r| records.push(r.clone())).expect("async run");
    AsyncTrace {
        events: exp.event_log.clone(),
        windows: window_rows(&records),
        params: exp.method.global_params().iter().map(|p| p.to_bits()).collect(),
    }
}

/// Extra thread count injected by the CI determinism matrix.
fn env_threads() -> Option<usize> {
    std::env::var("DTFL_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
}

/// Uplink codec forced by the CI determinism matrix (`DTFL_TEST_UPLINK`);
/// `raw` when unset. Goldens are recorded under the same codec in-process.
fn env_uplink() -> UplinkCodec {
    std::env::var("DTFL_TEST_UPLINK")
        .ok()
        .filter(|v| !v.is_empty())
        .map(|v| UplinkCodec::from_name(&v).expect("DTFL_TEST_UPLINK"))
        .unwrap_or(UplinkCodec::Raw)
}

/// One grid entry per supported non-scalar dispatch level (heavyweight
/// per-level coverage runs in the CI `DTFL_TEST_SIMD` legs).
fn simd_entries() -> impl Iterator<Item = Knobs> {
    simd::available()
        .into_iter()
        .filter(|&l| l != SimdLevel::Scalar)
        .map(|l| Knobs { threads: 2, intra: 1, depth: 4, shards: 0, fuse: true, simd: Some(l) })
}

fn full_grid() -> Vec<Knobs> {
    let mut g = vec![
        // fusion alone against the unfused sequential reference
        Knobs { threads: 1, intra: 1, depth: 1, shards: 1, fuse: true, simd: None },
        // pipelining/sharding alone, sequential pool, unfused
        Knobs { threads: 1, intra: 1, depth: 4, shards: 3, fuse: false, simd: None },
        // parallel pool with the barrier aggregator, unfused
        Knobs { threads: 2, intra: 1, depth: 1, shards: 1, fuse: false, simd: None },
        // parallel + pipelined + auto shards + fusion (the default engine)
        Knobs { threads: 4, intra: 1, depth: 4, shards: 0, fuse: true, simd: None },
        // everything composed, including intra-step kernel splits
        Knobs { threads: 4, intra: 2, depth: 8, shards: 2, fuse: true, simd: None },
    ];
    g.extend(simd_entries());
    if let Some(n) = env_threads() {
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: true, simd: None });
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: false, simd: None });
    }
    g
}

/// A smaller grid for the scenario-driven legs (the full grid runs on the
/// cheaper scenario-free session).
fn small_grid() -> Vec<Knobs> {
    let mut g = vec![
        Knobs { threads: 1, intra: 1, depth: 1, shards: 1, fuse: true, simd: None },
        Knobs { threads: 4, intra: 1, depth: 4, shards: 0, fuse: true, simd: None },
    ];
    g.extend(simd_entries());
    if let Some(n) = env_threads() {
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: true, simd: None });
    }
    g
}

fn assert_grid_invariant(
    label: &str,
    scenario: Option<&Scenario>,
    clients: usize,
    rounds: usize,
    grid: &[Knobs],
) -> AsyncTrace {
    let golden = run_async(scenario.cloned(), clients, rounds, 1, REFERENCE);
    assert!(!golden.events.is_empty(), "{label}: empty event stream");
    assert_eq!(golden.windows.len(), rounds, "{label}: one window row per round");
    for &k in grid {
        let t = run_async(scenario.cloned(), clients, rounds, 1, k);
        assert_eq!(
            golden.events, t.events,
            "{label} {k:?}: event-sequence golden trace diverged"
        );
        assert_eq!(golden.windows, t.windows, "{label} {k:?}: window rows diverged");
        assert_eq!(golden.params, t.params, "{label} {k:?}: global param bits diverged");
    }
    golden
}

/// Structural invariants every recorded stream must satisfy: processing
/// order is non-decreasing in time; equal timestamps resolve ClientFinish →
/// TierFlush → ServerBroadcast (the pinned straddle semantics); and every
/// broadcast publishes exactly what the latest same-instant flush merged.
fn assert_stream_well_formed(label: &str, events: &[EventRecord]) {
    for pair in events.windows(2) {
        let (a, b) = (&pair[0], &pair[1]);
        let (ta, tb) = (f64::from_bits(a.time_bits), f64::from_bits(b.time_bits));
        assert!(
            ta.total_cmp(&tb).is_le(),
            "{label}: stream out of time order ({ta} then {tb})"
        );
        if a.time_bits == b.time_bits {
            assert!(
                a.kind.rank() <= b.kind.rank(),
                "{label}: equal-time events out of kind-rank order ({:?} then {:?})",
                a.kind,
                b.kind
            );
        }
    }
    let mut last_flush_ck: Option<u64> = None;
    let mut last_flush_time = 0u64;
    for e in events {
        match e.kind {
            EventKind::ClientFinish => {
                let s = f64::from_bits(e.staleness_bits);
                assert!(s > 0.0 && s <= 1.0, "{label}: finish staleness weight out of (0,1]");
                assert_eq!(e.checksum, 0, "{label}: finish rows carry no checksum");
            }
            EventKind::TierFlush => {
                let beta = f64::from_bits(e.staleness_bits);
                assert!((0.0..=1.0).contains(&beta), "{label}: blend factor out of [0,1]");
                last_flush_ck = Some(e.checksum);
                last_flush_time = e.time_bits;
            }
            EventKind::ServerBroadcast => {
                assert_eq!(
                    Some(e.checksum),
                    last_flush_ck,
                    "{label}: broadcast must publish the latest flushed model"
                );
                assert_eq!(
                    e.time_bits, last_flush_time,
                    "{label}: broadcast shares its triggering flush's instant"
                );
            }
        }
    }
}

fn has_kind(events: &[EventRecord], kind: EventKind) -> bool {
    events.iter().any(|e| e.kind == kind)
}

#[test]
fn async_event_trace_is_knob_invariant() {
    let golden = assert_grid_invariant("async", None, 6, 3, &full_grid());
    assert!(has_kind(&golden.events, EventKind::ClientFinish));
    assert!(has_kind(&golden.events, EventKind::TierFlush));
    assert!(has_kind(&golden.events, EventKind::ServerBroadcast));
    assert_stream_well_formed("async", &golden.events);
    assert!(
        golden.params.iter().all(|&b| f32::from_bits(b).is_finite()),
        "async training must keep the global model finite"
    );
}

#[test]
fn straggler_heavy_event_trace_is_knob_invariant() {
    let sc = Scenario::parse(STRAGGLER_HEAVY_TOML).expect("committed scenario parses");
    assert_eq!(sc.total_clients(), 6);
    assert!(sc.deadline_secs.is_some() && !sc.links.is_empty());
    let golden = assert_grid_invariant("straggler-heavy", Some(&sc), 6, 4, &small_grid());
    assert_stream_well_formed("straggler-heavy", &golden.events);
}

/// The lossless uplink contract on the async engine: a `delta` session
/// reproduces the raw session's event stream, window rows, and parameter
/// bits exactly — only the uplink byte accounting shrinks. The event
/// queue orders on virtual time, which always charges the raw protocol,
/// so any divergence here means the codec leaked into the timing model.
#[test]
fn lossless_uplink_delta_is_bit_invisible_to_the_async_engine() {
    let sc = Scenario::parse(STRAGGLER_HEAVY_TOML).expect("committed scenario parses");
    let raw = run_async_with_uplink(Some(sc.clone()), 6, 4, 1, REFERENCE, UplinkCodec::Raw);
    let delta = run_async_with_uplink(Some(sc), 6, 4, 1, REFERENCE, UplinkCodec::Delta);
    assert_eq!(raw.events, delta.events, "delta codec perturbed the async event stream");
    assert_eq!(raw.params, delta.params, "delta codec perturbed async training bits");
    let sans_up = |ws: &[WindowRow]| -> Vec<WindowRow> {
        ws.iter()
            .cloned()
            .map(|mut w| {
                w.up_wire_bytes = 0;
                w
            })
            .collect()
    };
    assert_eq!(
        sans_up(&raw.windows),
        sans_up(&delta.windows),
        "the lossless delta codec may only change the uplink byte column"
    );
    let up = |t: &AsyncTrace| -> u64 { t.windows.iter().map(|w| w.up_wire_bytes).sum() };
    let (raw_up, delta_up) = (up(&raw), up(&delta));
    assert!(raw_up > 0, "async windows must account uplink bytes");
    assert!(delta_up < raw_up, "uplink delta must save bytes ({delta_up} vs {raw_up})");
}

/// The acceptance pin: on the committed straggler-heavy scenario the async
/// tier engine's makespan strictly beats both synchronous deadline
/// policies, final loss is no worse than `drop`'s, and the recorded event
/// stream is bit-identical across engine knobs. Exactly the probe the
/// `async_tiers` object in `BENCH_hotpath.json` records.
#[test]
fn straggler_heavy_async_beats_both_sync_policies() {
    let at = harness::measure_async_throughput(8).expect("async throughput probe");
    assert!(at.events > 0, "the async leg must process events");
    assert!(at.bit_identical, "async legs on different knobs must agree byte for byte");
    assert!(
        at.async_sim_secs < at.drop_sim_secs,
        "async makespan must beat the sync drop policy ({} vs {})",
        at.async_sim_secs,
        at.drop_sim_secs
    );
    assert!(
        at.drop_sim_secs < at.wait_sim_secs,
        "dropping stragglers must beat waiting on them ({} vs {})",
        at.drop_sim_secs,
        at.wait_sim_secs
    );
    assert!(
        at.async_final_test_loss <= at.drop_final_test_loss + 0.05,
        "async final loss must be no worse than drop's ({} vs {})",
        at.async_final_test_loss,
        at.drop_final_test_loss
    );
}

/// A tier whose every client churns out keeps flushing on cadence with an
/// empty buffer: β = 0 rows that carry the tier model forward unchanged
/// (same checksum as the previous flush) instead of stalling or resetting.
#[test]
fn fully_churned_out_tier_carries_model_forward() {
    let mut ephemeral = CohortSpec::new("ephemeral", 4, 1.0, 20.0);
    ephemeral.depart = Some(1); // everyone gone after the first window
    let sc = Scenario {
        name: "churn-out".into(),
        seed: 7,
        deadline_secs: None,
        on_deadline: DeadlinePolicy::Drop,
        delta_downlink: false,
        cohorts: vec![ephemeral],
        links: vec![],
    };
    let t = run_async(Some(sc), 4, 4, 4, REFERENCE);
    assert_eq!(t.windows.len(), 4, "the horizon is fully simulated despite the churn-out");
    let flushes: Vec<&EventRecord> =
        t.events.iter().filter(|e| e.kind == EventKind::TierFlush).collect();
    assert!(
        flushes.iter().any(|e| e.staleness_bits == 0.0f64.to_bits()),
        "a fully-departed tier must flush empty (β = 0) at least once"
    );
    // carry-forward: an empty flush leaves the model checksum exactly
    // where the same tier's previous flush left it
    let mut carried = 0usize;
    for (i, e) in flushes.iter().enumerate().skip(1) {
        if e.staleness_bits == 0.0f64.to_bits() {
            let prev = flushes[..i].iter().rev().find(|p| p.tier == e.tier);
            if let Some(p) = prev {
                assert_eq!(
                    e.checksum, p.checksum,
                    "empty flush of tier {} must carry the model forward",
                    e.tier
                );
                carried += 1;
            }
        }
    }
    assert!(carried > 0, "at least one empty flush follows a previous flush of its tier");
    assert!(t.params.iter().all(|&b| f32::from_bits(b).is_finite()));
}

/// Quarantined non-finite updates never enter a cross-tier merge: with
/// every client NaN-poisoned, every flush is an empty carry-forward, the
/// global model never moves, and every parameter stays finite.
#[test]
fn quarantined_updates_never_enter_a_merge() {
    let mut poisoned = CohortSpec::new("poisoned", 3, 1.0, 20.0);
    poisoned.corrupt_prob = 1.0;
    poisoned.corrupt_mode = CorruptMode::Nan;
    let sc = Scenario {
        name: "all-poisoned".into(),
        seed: 11,
        deadline_secs: None,
        on_deadline: DeadlinePolicy::Drop,
        delta_downlink: false,
        cohorts: vec![poisoned],
        links: vec![],
    };
    let t = run_async(Some(sc), 3, 3, 1, REFERENCE);
    let quarantined: usize = t.windows.iter().map(|w| w.quarantined).sum();
    assert!(quarantined > 0, "every delivered NaN update must be quarantined");
    let flushes: Vec<&EventRecord> =
        t.events.iter().filter(|e| e.kind == EventKind::TierFlush).collect();
    assert!(!flushes.is_empty());
    for e in &flushes {
        assert_eq!(
            e.staleness_bits,
            0.0f64.to_bits(),
            "no poisoned update may reach a merge (β must stay 0)"
        );
    }
    assert!(
        flushes.iter().all(|e| e.checksum == flushes[0].checksum),
        "with nothing merged the global checksum never changes"
    );
    assert!(
        t.windows.iter().all(|w| w.staleness == 0.0f64.to_bits()),
        "no merge means no staleness signal"
    );
    assert!(
        t.params.iter().all(|&b| f32::from_bits(b).is_finite()),
        "quarantine must keep the global model finite"
    );
}

/// The `wait`-policy accounting fix: a flaky uplink's retry backoff is
/// charged exactly once in virtual time, not once per flush window the
/// attempt spans. Two sessions identical except the backoff base must
/// differ in the flaky client's first finish time by exactly
/// `B·(2^(retry_max+1) − 1)` — the one-shot exponential backoff sum —
/// even though that span crosses tier-flush boundaries, and the flush
/// stream itself must be untouched (the lost update never merges).
#[test]
fn retry_backoff_is_charged_once_across_flush_windows() {
    let session = |backoff: f64| {
        let steady = CohortSpec::new("steady", 3, 1.0, 2.0);
        let mut flaky = CohortSpec::new("flaky", 1, 1.0, 2.0);
        flaky.link_fail_prob = 1.0; // every attempt fails, deterministically
        flaky.retry_max = 2;
        flaky.retry_backoff_secs = backoff;
        let sc = Scenario {
            name: "flaky-charge".into(),
            seed: 3,
            deadline_secs: None,
            on_deadline: DeadlinePolicy::Wait,
            delta_downlink: false,
            cohorts: vec![steady, flaky],
            links: vec![],
        };
        run_async(Some(sc), 4, 24, 24, REFERENCE)
    };
    let base = session(0.0);
    let charged = session(0.5);
    let first_finish = |t: &AsyncTrace| {
        t.events
            .iter()
            .find(|e| e.kind == EventKind::ClientFinish && e.client == 3)
            .map(|e| (f64::from_bits(e.time_bits), e.tier))
            .expect("the flaky client's first finish lands within the horizon")
    };
    let (t0, tier) = first_finish(&base);
    let (t1, _) = first_finish(&charged);
    // backoff 0.5 doubling per failed attempt, retry_max + 1 = 3 failures:
    // 0.5 + 1.0 + 2.0 = 3.5 s, charged exactly once
    let expected = 0.5 * (1.0 + 2.0 + 4.0);
    assert!(
        ((t1 - t0) - expected).abs() < 1e-9,
        "retry backoff must be charged once: finish delta {} vs expected {expected}",
        t1 - t0
    );
    // the charged attempt really does span tier-flush boundaries
    let flushes_crossed = charged
        .events
        .iter()
        .filter(|e| {
            e.kind == EventKind::TierFlush && e.tier == tier && f64::from_bits(e.time_bits) < t1
        })
        .count();
    assert!(
        flushes_crossed >= 1,
        "the flaky attempt must cross at least one tier-flush boundary"
    );
    // the lost update never merges, so the flush/broadcast stream (β values
    // and checksums) is identical whatever the backoff costs
    let merges = |t: &AsyncTrace| -> Vec<EventRecord> {
        t.events.iter().filter(|e| e.kind != EventKind::ClientFinish).cloned().collect()
    };
    assert_eq!(merges(&base), merges(&charged), "backoff accounting must not leak into merges");
    let retries: usize = charged.windows.iter().map(|w| w.retries).sum();
    assert!(retries > 0, "the failed attempts must be charged as retries");
}
