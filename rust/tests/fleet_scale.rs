//! Fleet-scale contracts for the cohort-vectorized engine:
//!
//! * the committed `scenarios/mega_fleet.toml` (10^6 clients) is pinned
//!   field-for-field against the programmatic `harness::fleet_scenario`
//!   builder, so the bench legs and the committed scenario cannot drift;
//! * with delta downlink on, snapshot-store residency stays bounded by
//!   O(distinct broadcast rounds × params) — never O(fleet × params);
//! * (release smoke, `--ignored`) the 10^6-client scenario runs whole DTFL
//!   rounds, and per-round coordinator overhead grows sublinearly in fleet
//!   size at a fixed participant count.

use dtfl::experiment::Experiment;
use dtfl::harness::{fleet_scenario, measure_fleet_scale, RunSpec, MEGA_FLEET_TOML};
use dtfl::simulation::Scenario;

#[test]
fn committed_mega_fleet_toml_matches_programmatic_builder() {
    let parsed = Scenario::parse(MEGA_FLEET_TOML).expect("mega-fleet scenario parses");
    assert_eq!(parsed.total_clients(), 1_000_000);
    assert!(parsed.delta_downlink, "the snapshot store must be exercised");
    assert_eq!(parsed, fleet_scenario(1_000_000), "TOML and builder drifted apart");
    // smaller sizes keep the same shape and always sum exactly
    for k in [50usize, 10_000] {
        assert_eq!(fleet_scenario(k).total_clients(), k);
    }
}

#[test]
fn resident_snapshot_bytes_stay_bounded_at_ten_thousand_clients() {
    let fleet = 10_000usize;
    let rounds = 3usize;
    let spec = RunSpec {
        clients: fleet,
        rounds,
        batch_cap: Some(1),
        train_total: 512,
        test_total: 16,
        eval_every: rounds,
        fleet: "cohort".into(),
        sample_count: Some(10),
        scenario: Some(fleet_scenario(fleet)),
        ..Default::default()
    };
    let mut exp = Experiment::new(spec.to_config()).expect("fleet experiment");
    let mut records = Vec::new();
    exp.run_with(|r| records.push(r.clone())).expect("fleet run");
    assert_eq!(records.len(), rounds);

    let params = exp.method.global_params().len() as u64;
    let bound = rounds as u64 * params * 4;
    let per_client_cost = fleet as u64 * params * 4;
    assert!(bound < per_client_cost, "the bound must beat O(fleet × params)");
    for r in &records {
        assert!(r.snapshot_resident_bytes > 0, "round {}: resident gauge must be live", r.round);
        assert!(
            r.snapshot_resident_bytes <= bound,
            "round {}: {} resident bytes exceed the O(distinct rounds × params) bound {}",
            r.round,
            r.snapshot_resident_bytes,
            bound
        );
        assert!(
            (1..=3).contains(&r.cohort_advances),
            "round {}: fleet must advance at cohort granularity (got {})",
            r.round,
            r.cohort_advances
        );
    }
}

/// Release-mode large-K smoke (CI: `cargo test --release -q --test
/// fleet_scale -- --ignored`): the committed 10^6-client scenario runs
/// whole DTFL rounds, residency honors its bound at every size, and the
/// coordinator's per-round overhead grows sublinearly along the fleet axis.
#[test]
#[ignore = "large-K smoke; run in release with -- --ignored"]
fn mega_fleet_runs_and_coordinator_overhead_is_sublinear() {
    let t = measure_fleet_scale(&[50, 10_000, 1_000_000], 3).expect("fleet-scale probe");
    assert_eq!(t.legs.len(), 3);
    for l in &t.legs {
        assert_eq!(l.rounds, 3, "leg {}: every round must complete", l.fleet);
        assert!(
            l.mean_makespan_secs.is_finite() && l.mean_makespan_secs > 0.0,
            "leg {}: makespan must be simulated",
            l.fleet
        );
        assert!(l.resident_bytes > 0, "leg {}: resident gauge must be live", l.fleet);
        assert!(
            l.resident_bytes <= l.resident_bound_bytes,
            "leg {}: {} resident bytes exceed bound {}",
            l.fleet,
            l.resident_bytes,
            l.resident_bound_bytes
        );
        assert!(l.cohort_advances <= 3, "leg {}: advances bounded by the cohort count", l.fleet);
    }
    // the fleet grows 100× between the last two legs at a fixed participant
    // count; per-round coordinator overhead must grow far less (generous
    // margin for shared-runner timing noise)
    let mid = t.legs[1].coordinator_secs_per_round.max(1e-9);
    let big = t.legs[2].coordinator_secs_per_round;
    assert!(
        big < mid * 20.0,
        "coordinator overhead grew superlinearly: {big:.6}s/round at 10^6 vs {mid:.6}s/round at 10^4"
    );
}
