//! Property tests for the async event queue's ordering contract and the
//! staleness-weighted merge arithmetic (`simulation::events`).
//!
//! Randomized over many seeds: event insertion order never changes the pop
//! order (the queue's total order is a pure function of the event *set*);
//! equal-timestamp ties always resolve by the pinned
//! `(kind rank, tier, client, seq)` key; the staleness discount is
//! monotone non-increasing in rounds-behind; and every tier flush
//! preserves the weight-sum invariant `β·fleet_w = min(Σ wᵢ·s(dᵢ),
//! fleet_w)` with per-update weights never amplified.

use dtfl::simulation::{
    staleness_merge, staleness_weight, Event, EventKind, EventQueue, NO_CLIENT,
};
use dtfl::util::Rng64;

const KINDS: [EventKind; 3] =
    [EventKind::ClientFinish, EventKind::TierFlush, EventKind::ServerBroadcast];

/// A random event; times are drawn from a small lattice so equal-timestamp
/// collisions (the interesting case) are common.
fn random_event(rng: &mut Rng64, seq: u64) -> Event {
    let kind = KINDS[rng.gen_range(0, 3)];
    let client = if kind == EventKind::ClientFinish { rng.gen_range(0, 8) } else { NO_CLIENT };
    Event {
        time: rng.gen_range(0, 12) as f64 * 0.25,
        kind,
        client,
        tier: 1 + rng.gen_range(0, 4),
        seq,
    }
}

fn pop_all(q: &mut EventQueue) -> Vec<Event> {
    std::iter::from_fn(|| q.pop()).collect()
}

fn key_of(e: &Event) -> (u8, usize, usize, u64) {
    (e.kind.rank(), e.tier, e.client, e.seq)
}

#[test]
fn pop_order_is_a_pure_function_of_the_event_set() {
    for seed in 0..32u64 {
        let mut rng = Rng64::seed_from_u64(seed);
        let n = 1 + rng.gen_range(0, 64);
        let events: Vec<Event> = (0..n).map(|i| random_event(&mut rng, i as u64)).collect();

        // the specified order: (total_cmp on time, pinned key)
        let mut expected = events.clone();
        expected.sort_by(|a, b| a.time.total_cmp(&b.time).then_with(|| key_of(a).cmp(&key_of(b))));

        // insertion order must be irrelevant: original vs shuffled
        let mut q = EventQueue::new();
        for &e in &events {
            q.push_event(e);
        }
        let popped = pop_all(&mut q);
        assert_eq!(popped, expected, "seed {seed}: pop order violates the (time, key) order");

        let mut shuffled = events.clone();
        rng.shuffle(&mut shuffled);
        let mut q2 = EventQueue::new();
        for &e in &shuffled {
            q2.push_event(e);
        }
        assert_eq!(
            pop_all(&mut q2),
            popped,
            "seed {seed}: shuffled insertion changed the pop order"
        );
    }
}

#[test]
fn pop_order_never_violates_the_total_order() {
    for seed in 100..120u64 {
        let mut rng = Rng64::seed_from_u64(seed);
        let mut q = EventQueue::new();
        for i in 0..50u64 {
            q.push_event(random_event(&mut rng, i));
        }
        let popped = pop_all(&mut q);
        for pair in popped.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            assert!(
                a.time.total_cmp(&b.time).is_le(),
                "seed {seed}: time order violated ({} before {})",
                a.time,
                b.time
            );
            if a.time.to_bits() == b.time.to_bits() {
                assert!(
                    key_of(a) < key_of(b),
                    "seed {seed}: equal-time tie not resolved by the pinned key"
                );
            }
        }
    }
}

#[test]
fn equal_timestamp_ties_resolve_by_pinned_key_regardless_of_insertion() {
    for seed in 200..216u64 {
        let mut rng = Rng64::seed_from_u64(seed);
        // every event at the same instant: ordering is the key alone
        let events: Vec<Event> = (0..24u64)
            .map(|i| Event { time: 3.5, ..random_event(&mut rng, i) })
            .collect();
        let mut expected = events.clone();
        expected.sort_by(|a, b| key_of(a).cmp(&key_of(b)));
        for trial in 0..4 {
            let mut shuffled = events.clone();
            rng.shuffle(&mut shuffled);
            let mut q = EventQueue::new();
            for &e in &shuffled {
                q.push_event(e);
            }
            assert_eq!(
                pop_all(&mut q),
                expected,
                "seed {seed} trial {trial}: tie-break depended on insertion order"
            );
        }
    }
}

#[test]
fn auto_sequencing_preserves_fifo_among_identical_events() {
    // push() assigns monotone seq numbers, so two otherwise-identical
    // events pop in insertion order — the last resort of the pinned key
    let mut q = EventQueue::new();
    let a = q.push(1.0, EventKind::TierFlush, NO_CLIENT, 2);
    let b = q.push(1.0, EventKind::TierFlush, NO_CLIENT, 2);
    assert!(a.seq < b.seq);
    let popped = pop_all(&mut q);
    assert_eq!(popped[0].seq, a.seq);
    assert_eq!(popped[1].seq, b.seq);
}

#[test]
fn staleness_weight_is_monotone_non_increasing_from_one() {
    assert_eq!(staleness_weight(0), 1.0, "a fresh update is not discounted");
    let mut prev = staleness_weight(0);
    for d in 1..=256 {
        let w = staleness_weight(d);
        assert!(w > 0.0 && w <= 1.0, "s({d}) = {w} out of (0, 1]");
        assert!(w <= prev, "s({d}) = {w} > s({}) = {prev}: not monotone", d - 1);
        prev = w;
    }
}

#[test]
fn staleness_merge_preserves_the_weight_sum_invariant() {
    for seed in 300..332u64 {
        let mut rng = Rng64::seed_from_u64(seed);
        let n = 1 + rng.gen_range(0, 12);
        let base: Vec<f64> = (0..n).map(|_| rng.gen_f64(1.0, 200.0)).collect();
        let behind: Vec<usize> = (0..n).map(|_| rng.gen_range(0, 6)).collect();
        let fleet_w: f64 = rng.gen_f64(50.0, 2000.0);
        let (scaled, beta) = staleness_merge(&base, &behind, fleet_w);
        assert_eq!(scaled.len(), n);

        // per-update: scaled exactly w·s(d), never amplified
        let mut sum = 0.0f64;
        for i in 0..n {
            let expect = base[i] * staleness_weight(behind[i]);
            assert_eq!(scaled[i].to_bits(), expect.to_bits(), "seed {seed}: scale mismatch");
            assert!(scaled[i] <= base[i], "seed {seed}: staleness must never amplify a weight");
            if behind[i] == 0 {
                assert_eq!(scaled[i].to_bits(), base[i].to_bits(), "fresh weight untouched");
            }
            sum += scaled[i];
        }
        // the flush invariant, bit-exact in the pinned accumulation order:
        // β·fleet_w recovers the scaled weight mass (clamped at fleet_w)
        let expect_beta = (sum / fleet_w).min(1.0);
        assert_eq!(beta.to_bits(), expect_beta.to_bits(), "seed {seed}: β mismatch");
        assert!((0.0..=1.0).contains(&beta), "seed {seed}: β = {beta} out of [0, 1]");
    }
}

#[test]
fn stale_mix_weighs_less_than_the_same_fresh_mix() {
    for seed in 400..416u64 {
        let mut rng = Rng64::seed_from_u64(seed);
        let n = 2 + rng.gen_range(0, 8);
        let base: Vec<f64> = (0..n).map(|_| rng.gen_f64(1.0, 100.0)).collect();
        let fresh = vec![0usize; n];
        let stale: Vec<usize> = (0..n).map(|_| 1 + rng.gen_range(0, 5)).collect();
        let fleet_w = 10_000.0; // far from the clamp
        let (_, beta_fresh) = staleness_merge(&base, &fresh, fleet_w);
        let (_, beta_stale) = staleness_merge(&base, &stale, fleet_w);
        assert!(
            beta_stale < beta_fresh,
            "seed {seed}: a strictly stale mix must move the global model less"
        );
    }
}
