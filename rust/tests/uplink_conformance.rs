//! Uplink codec conformance + fuzz suite (wire-efficiency layer 2).
//!
//! The uplink packets are a real wire format, so they get wire-format
//! tests: seeded-random round trips over awkward shapes (empty, length-1,
//! chunk-boundary sizes), exact survival of the IEEE special values
//! through the lossless codecs, hardened rejection of truncated and
//! corrupted payloads (with the client id and byte offset in the error,
//! never a panic), the top-k error-feedback partition invariant
//! (residual + sent == full delta, bit for bit), and the `prox_mu = 0` /
//! `uplink = raw` defaults being exactly the legacy training path.

use dtfl::coordinator::uplink::{
    apply_packet, encode_packet, topk_k, UplinkCodec, UplinkSession,
};
use dtfl::coordinator::FoldStrategy;
use dtfl::experiment::Experiment;
use dtfl::harness::RunSpec;

/// xorshift64* — a seeded in-test generator (the repo has no RNG crate,
/// and the suite must be reproducible anyway).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Self(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// A value in roughly [-10.3, 10.3] on a lattice (collisions — and so
    /// zero deltas — are possible and intentionally exercised).
    fn val(&mut self) -> f32 {
        ((self.next() % 2001) as f32 - 1000.0) / 97.0
    }

    fn vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.val()).collect()
    }
}

/// Shapes that hit the format's corners: empty, singleton, the `int8`
/// chunk boundary (255/256/257), and a multi-chunk tail.
const SHAPES: [usize; 12] = [0, 1, 2, 7, 63, 255, 256, 257, 300, 511, 513, 1000];

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

#[test]
fn lossless_codecs_round_trip_every_shape_bitwise() {
    let mut rng = Rng::new(0x5eed);
    for &n in &SHAPES {
        let base = rng.vec(n);
        // a realistic update: mostly small perturbations, a few jumps
        let cur: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(i, &b)| if i % 17 == 0 { rng.val() } else { b + 1e-3 })
            .collect();
        for codec in [UplinkCodec::Raw, UplinkCodec::Delta] {
            let p = encode_packet(codec, &base, &cur, None);
            let back = apply_packet(&base, &p, 42).expect("lossless decode");
            assert_bits_eq(&back, &cur, &format!("{} n={n}", codec.name()));
            // a base of the wrong length is a protocol violation, not a panic
            if n > 0 {
                let err = apply_packet(&base[..n - 1], &p, 42).unwrap_err().to_string();
                assert!(err.contains("client 42"), "{err}");
            }
        }
    }
}

#[test]
fn special_values_survive_lossless_codecs_exactly() {
    let cur = vec![
        f32::NAN,
        f32::from_bits(0x7fc1_2345), // NaN with a payload
        f32::INFINITY,
        f32::NEG_INFINITY,
        -0.0,
        0.0,
        f32::MIN_POSITIVE,
        1e-41, // subnormal
        -1.5,
        f32::MAX,
    ];
    let base = vec![0.25f32; cur.len()];
    for codec in [UplinkCodec::Raw, UplinkCodec::Delta] {
        let p = encode_packet(codec, &base, &cur, None);
        let back = apply_packet(&base, &p, 0).expect("lossless decode");
        assert_bits_eq(&back, &cur, codec.name());
    }
    // poisoned updates must reach the server unchanged through the lossy
    // codecs too: topk falls back to an explicit raw packet, and int8
    // passes the whole non-finite chunk through raw
    for codec in [UplinkCodec::TopK, UplinkCodec::Int8] {
        let p = encode_packet(codec, &base, &cur, None);
        let back = apply_packet(&base, &p, 0).expect("passthrough decode");
        assert_bits_eq(&back, &cur, &format!("{} non-finite passthrough", codec.name()));
    }
}

#[test]
fn lossy_codecs_decode_within_their_contract() {
    let mut rng = Rng::new(0xfeed);
    for &n in &SHAPES {
        let base = rng.vec(n);
        let cur = rng.vec(n);

        // int8: every coordinate lands within half a quantization step
        let p = encode_packet(UplinkCodec::Int8, &base, &cur, None);
        let dec = apply_packet(&base, &p, 0).expect("int8 decode");
        assert_eq!(dec.len(), n);
        for (ci, chunk) in cur.chunks(256).enumerate() {
            let lo = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let step = (hi - lo) / 255.0;
            for (j, &v) in chunk.iter().enumerate() {
                let d = dec[ci * 256 + j];
                assert!(
                    (d - v).abs() <= step * 0.5 + 1e-6,
                    "int8 n={n} chunk {ci} coord {j}: {d} vs {v} (step {step})"
                );
            }
        }

        // topk: at most k coordinates move, each to base + delta exactly
        let p = encode_packet(UplinkCodec::TopK, &base, &cur, None);
        let dec = apply_packet(&base, &p, 0).expect("topk decode");
        let mut moved = 0usize;
        for i in 0..n {
            if dec[i].to_bits() != base[i].to_bits() {
                moved += 1;
                let d = (cur[i] - base[i]) + 0.0;
                assert_eq!(
                    dec[i].to_bits(),
                    (base[i] + d).to_bits(),
                    "topk n={n} coord {i}: sent coordinate must be base + delta"
                );
            }
        }
        assert!(moved <= topk_k(n), "topk n={n}: moved {moved} > k {}", topk_k(n));
    }
}

#[test]
fn truncated_packets_are_rejected_never_panic() {
    let mut rng = Rng::new(0xcafe);
    let n = 300;
    let base = rng.vec(n);
    let cur = rng.vec(n);
    // raw / int8 / topk: every strict prefix is a protocol violation
    for codec in [UplinkCodec::Raw, UplinkCodec::Int8, UplinkCodec::TopK] {
        let p = encode_packet(codec, &base, &cur, None);
        for cut in 0..p.len() {
            let err = apply_packet(&base, &p[..cut], 7)
                .err()
                .unwrap_or_else(|| panic!("{}: truncation at {cut} decoded", codec.name()))
                .to_string();
            assert!(err.contains("client 7"), "{}: cut {cut}: {err}", codec.name());
        }
    }
    // delta wraps the snapshot-delta format: a prefix must either be
    // rejected with the client id, or — if some prefix happens to parse —
    // still reproduce the exact update (hardened, never wrong, never a
    // panic)
    let p = encode_packet(UplinkCodec::Delta, &base, &cur, None);
    assert!(apply_packet(&base, &p[..0], 7).is_err());
    for cut in 0..p.len() {
        match apply_packet(&base, &p[..cut], 7) {
            Err(e) => assert!(e.to_string().contains("client 7"), "{e}"),
            Ok(v) => assert_bits_eq(&v, &cur, &format!("delta prefix {cut}")),
        }
    }
    // mid-payload truncations report where the stream broke
    let p = encode_packet(UplinkCodec::Raw, &base, &cur, None);
    let err = apply_packet(&base, &p[..p.len() - 1], 7).unwrap_err().to_string();
    assert!(err.contains("offset"), "{err}");
}

#[test]
fn corrupted_packets_are_rejected_with_client_and_offset() {
    let mut rng = Rng::new(0xbead);
    let n = 300;
    let base = rng.vec(n);
    let cur = rng.vec(n);

    // unknown codec tag
    let mut bad = encode_packet(UplinkCodec::Raw, &base, &cur, None);
    bad[0] = 9;
    let err = apply_packet(&base, &bad, 3).unwrap_err().to_string();
    assert!(err.contains("client 3") && err.contains("unknown uplink codec tag 9"), "{err}");

    // element-count mismatch vs the base snapshot
    let mut bad = encode_packet(UplinkCodec::Raw, &base, &cur, None);
    bad[1..5].copy_from_slice(&((n as u32) + 1).to_le_bytes());
    let err = apply_packet(&base, &bad, 3).unwrap_err().to_string();
    assert!(err.contains("client 3") && err.contains("301 params"), "{err}");

    // bad int8 chunk flag (first flag byte sits right after the header)
    let mut bad = encode_packet(UplinkCodec::Int8, &base, &cur, None);
    bad[5] = 7;
    let err = apply_packet(&base, &bad, 3).unwrap_err().to_string();
    assert!(
        err.contains("client 3") && err.contains("bad int8 chunk flag 7") && err.contains("offset"),
        "{err}"
    );

    // topk claiming more coordinates than the vector holds
    let mut bad = encode_packet(UplinkCodec::TopK, &base, &cur, None);
    bad[5..9].copy_from_slice(&((n as u32) + 1).to_le_bytes());
    let err = apply_packet(&base, &bad, 3).unwrap_err().to_string();
    assert!(err.contains("client 3") && err.contains("offset"), "{err}");

    // a varint driven past 32 bits of index space
    let mut bad = encode_packet(UplinkCodec::TopK, &base, &cur, None);
    for b in &mut bad[9..14] {
        *b = 0xFF;
    }
    let err = apply_packet(&base, &bad, 3).unwrap_err().to_string();
    assert!(err.contains("client 3"), "{err}");
}

/// The error-feedback invariant: after every `topk` upload, the kept
/// residual and the sent coordinates partition the full-precision delta
/// `(cur − base) + carry` exactly — no mass is created or lost, bit for
/// bit, across rounds (the carry feeds the next round's delta).
#[test]
fn topk_residual_partitions_the_full_delta_bitwise() {
    let mut rng = Rng::new(0xace);
    let n = 200;
    let s = UplinkSession::new(UplinkCodec::TopK, 1);
    let mut carry = vec![0.0f32; n];
    for round in 0..3 {
        let base = rng.vec(n);
        let mut cur = rng.vec(n);
        // the exact expression topk_delta computes, replicated coordinate-
        // wise: (cur - base) + carry
        let d: Vec<f32> = (0..n).map(|i| (cur[i] - base[i]) + carry[i]).collect();
        let coded = s.encode_update(0, &base, &mut cur, 4 * n);
        assert!(coded < 4 * n, "round {round}: topk must beat raw at n={n}");
        let resid = s.residual(0).expect("topk leaves a residual");
        assert_eq!(resid.len(), n);
        for i in 0..n {
            if resid[i] != 0.0 {
                // withheld: the residual carries the full delta and the
                // wire carries nothing
                assert_eq!(
                    resid[i].to_bits(),
                    d[i].to_bits(),
                    "round {round} coord {i}: residual must equal the unsent delta"
                );
                assert_eq!(
                    cur[i].to_bits(),
                    base[i].to_bits(),
                    "round {round} coord {i}: unsent coordinate must stay at base"
                );
            } else {
                // sent (or a zero delta): the wire carries the full delta
                assert_eq!(
                    cur[i].to_bits(),
                    (base[i] + d[i]).to_bits(),
                    "round {round} coord {i}: sent coordinate must be base + delta"
                );
            }
        }
        carry = resid;
    }
}

/// Smallest-wins: a payload the codec cannot beat ships raw — untouched
/// update, no residual, raw accounting.
#[test]
fn tiny_payloads_fall_back_to_raw_untouched() {
    let s = UplinkSession::new(UplinkCodec::TopK, 1);
    let base = vec![1.0f32];
    let mut cur = vec![2.0f32];
    let coded = s.encode_update(0, &base, &mut cur, 4);
    assert_eq!(coded, 4, "a 1-element topk packet can never beat 4 raw bytes");
    assert_eq!(cur[0].to_bits(), 2.0f32.to_bits(), "raw fallback must not transform");
    assert!(!s.has_residual(0), "raw fallback must not leave a residual");
}

/// `prox_mu = 0` (the default) is gated to the exact legacy instruction
/// stream: repeat runs are bit-identical, and a nonzero μ really changes
/// training (while keeping it finite).
#[test]
fn prox_mu_zero_is_the_legacy_path_and_nonzero_mu_acts() {
    let run = |prox_mu: f32| -> (Vec<u64>, Vec<u32>) {
        let spec = RunSpec {
            method: "dtfl".into(),
            clients: 6,
            rounds: 2,
            batch_cap: Some(1),
            train_total: 96,
            test_total: 32,
            eval_every: 1,
            threads: 1,
            prox_mu,
            ..Default::default()
        };
        let mut exp = Experiment::new(spec.to_config()).expect("experiment");
        let mut losses = Vec::new();
        exp.run_with(|r| losses.push(r.train_loss.to_bits())).expect("run");
        (losses, exp.method.global_params().iter().map(|p| p.to_bits()).collect())
    };
    let (l0, p0) = run(0.0);
    let (l0b, p0b) = run(0.0);
    assert_eq!(l0, l0b, "μ = 0 must be deterministic");
    assert_eq!(p0, p0b, "μ = 0 must be deterministic");
    let (l1, p1) = run(0.1);
    assert_ne!(p0, p1, "a nonzero proximal term must change training");
    assert!(l1.iter().all(|&b| f64::from_bits(b).is_finite()), "μ > 0 must stay finite");
    assert!(p1.iter().all(|&b| f32::from_bits(b).is_finite()), "μ > 0 must stay finite");
}

/// The adaptive fold drives a full experiment to a finite model (its
/// degenerate-case bit-identity with `mean` is pinned at the unit level
/// in `coordinator::aggregate`).
#[test]
fn adaptive_fold_trains_to_a_finite_model() {
    let spec = RunSpec {
        method: "dtfl".into(),
        clients: 6,
        rounds: 2,
        batch_cap: Some(1),
        train_total: 96,
        test_total: 32,
        eval_every: 1,
        fold: FoldStrategy::Adaptive,
        ..Default::default()
    };
    let mut exp = Experiment::new(spec.to_config()).expect("experiment");
    exp.run_with(|_| {}).expect("run");
    assert!(exp.method.global_params().iter().all(|p| p.is_finite()));
}
