//! Property-based tests (hand-rolled randomized driver — no proptest crate
//! on this offline testbed). Each property runs against a few hundred
//! seeded random cases; failures print the seed for reproduction.

use dtfl::coordinator::{
    aggregate, schedule, ClientLoad, ClientUpdate, GlobalModel, Profiler, TierProfile,
};
use dtfl::data::{partition, patch_shuffle, synth, PartitionScheme};
use dtfl::runtime::Metadata;
use dtfl::simulation::ServerModel;
use dtfl::util::json;
use dtfl::util::Rng64;

fn tiny_meta() -> Option<Metadata> {
    let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    Metadata::load(&d).ok()
}

/// Drive `prop` over `cases` seeded random cases.
fn forall(cases: u64, mut prop: impl FnMut(&mut Rng64, u64)) {
    for seed in 0..cases {
        let mut rng = Rng64::seed_from_u64(0xbeef ^ seed);
        prop(&mut rng, seed);
    }
}

// ---------------------------------------------------------------------
// aggregation invariants
// ---------------------------------------------------------------------

#[test]
fn prop_aggregation_preserves_constant_models() {
    // if every client holds the SAME value v everywhere, the aggregate is v
    // regardless of tier mixture and weights
    let Some(meta) = tiny_meta() else { return };
    forall(50, |rng, seed| {
        let v = rng.gen_f32(-3.0, 3.0);
        let k = rng.gen_range(1, 8);
        let prev = GlobalModel::new(
            vec![0.0; meta.total_params],
            meta.tiers.iter().map(|t| vec![0.0; t.aux_len]).collect(),
            &meta,
        );
        let updates: Vec<ClientUpdate> = (0..k)
            .map(|i| {
                let tier = rng.gen_range(1, meta.max_tiers + 1);
                let t = meta.tier(tier);
                ClientUpdate {
                    client_id: i,
                    tier,
                    weight: rng.gen_f64(1.0, 500.0),
                    client_vec: vec![v; t.client_vec_len],
                    server_vec: vec![v; t.server_vec_len],
                }
            })
            .collect();
        let g = aggregate(&meta, &prev, &updates).unwrap();
        for (i, &x) in g.flat.iter().enumerate() {
            assert!(
                (x - v).abs() < 1e-4,
                "seed {seed}: flat[{i}]={x} expected {v}"
            );
        }
    });
}

#[test]
fn prop_aggregation_is_convex_combination() {
    // every aggregated coordinate lies within [min, max] of contributions
    let Some(meta) = tiny_meta() else { return };
    forall(30, |rng, seed| {
        let k = rng.gen_range(2, 6);
        let prev = GlobalModel::new(
            vec![0.0; meta.total_params],
            meta.tiers.iter().map(|t| vec![0.0; t.aux_len]).collect(),
            &meta,
        );
        let vals: Vec<f32> = (0..k).map(|_| rng.gen_f32(-2.0, 2.0)).collect();
        let updates: Vec<ClientUpdate> = vals
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let tier = rng.gen_range(1, meta.max_tiers + 1);
                let t = meta.tier(tier);
                ClientUpdate {
                    client_id: i,
                    tier,
                    weight: rng.gen_f64(1.0, 100.0),
                    client_vec: vec![v; t.client_vec_len],
                    server_vec: vec![v; t.server_vec_len],
                }
            })
            .collect();
        let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min) - 1e-4;
        let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max) + 1e-4;
        let g = aggregate(&meta, &prev, &updates).unwrap();
        assert!(
            g.flat.iter().all(|&x| (lo..=hi).contains(&x)),
            "seed {seed}: aggregate escaped the convex hull [{lo}, {hi}]"
        );
    });
}

// ---------------------------------------------------------------------
// scheduler invariants
// ---------------------------------------------------------------------

#[test]
fn prop_scheduler_respects_tmax_and_bounds() {
    let Some(meta) = tiny_meta() else { return };
    let profile = TierProfile {
        client_batch_secs: (0..meta.max_tiers).map(|i| 0.05 + 0.03 * i as f64).collect(),
        server_batch_secs: (0..meta.max_tiers).map(|i| 0.3 - 0.04 * i as f64).collect(),
    };
    let server = ServerModel::default();
    forall(100, |rng, seed| {
        let k = rng.gen_range(1, 20);
        let mut prof = Profiler::new(profile.clone(), k, 0.5);
        for i in 0..k {
            // random client speeds spanning 100x and random link speeds
            prof.observe(
                i,
                rng.gen_range(1, meta.max_tiers + 1),
                rng.gen_f64(0.01, 1.0),
                rng.gen_f64(1e5, 1e8),
            );
        }
        let loads: Vec<ClientLoad> = (0..k)
            .map(|_| ClientLoad { n_batches: rng.gen_range(1, 10), participating: true })
            .collect();
        let s = schedule(&meta, &prof, &server, &loads, meta.max_tiers);
        assert_eq!(s.assignments.len(), k, "seed {seed}");
        for a in &s.assignments {
            assert!(
                (1..=meta.max_tiers).contains(&a.tier),
                "seed {seed}: tier {} out of range",
                a.tier
            );
            // T_max is achievable by everyone: best estimate <= T_max
            assert!(
                a.est_best_secs <= s.t_max + 1e-9,
                "seed {seed}: client {} best {} > t_max {}",
                a.client_id,
                a.est_best_secs,
                s.t_max
            );
            // assigned tier estimate never exceeds T_max (minimized makespan)
            assert!(
                a.est_secs <= s.t_max + 1e-6,
                "seed {seed}: client {} est {} > t_max {}",
                a.client_id,
                a.est_secs,
                s.t_max
            );
        }
    });
}

#[test]
fn prop_scheduler_monotone_in_client_speed() {
    // making a client strictly slower (same link) never raises its tier
    let Some(meta) = tiny_meta() else { return };
    let profile = TierProfile {
        client_batch_secs: (0..meta.max_tiers).map(|i| 0.05 + 0.03 * i as f64).collect(),
        server_batch_secs: (0..meta.max_tiers).map(|i| 0.3 - 0.04 * i as f64).collect(),
    };
    let server = ServerModel::default();
    forall(60, |rng, seed| {
        let base = rng.gen_f64(0.01, 0.5);
        let slow_factor = rng.gen_f64(1.5, 30.0);
        let nu = rng.gen_f64(1e6, 1e8);
        let mk = |speed: f64| {
            let mut prof = Profiler::new(profile.clone(), 2, 0.5);
            prof.observe(0, 3, speed, nu);
            prof.observe(1, 3, 0.05, nu); // anchor client fixes T_max scale
            prof
        };
        let loads = vec![ClientLoad { n_batches: 4, participating: true }; 2];
        let fast = schedule(&meta, &mk(base), &server, &loads, meta.max_tiers);
        let slow = schedule(&meta, &mk(base * slow_factor), &server, &loads, meta.max_tiers);
        assert!(
            slow.tier_of(0) <= fast.tier_of(0),
            "seed {seed}: slower client got higher tier ({} > {})",
            slow.tier_of(0),
            fast.tier_of(0)
        );
    });
}

// ---------------------------------------------------------------------
// data invariants
// ---------------------------------------------------------------------

#[test]
fn prop_partition_is_disjoint_cover() {
    forall(20, |rng, seed| {
        let n = rng.gen_range(20, 300);
        let clients = rng.gen_range(1, 12);
        let spec = synth::DatasetSpec::tiny(n, 8);
        let ds = synth::generate_train(&spec);
        let scheme = if seed % 2 == 0 {
            PartitionScheme::Iid
        } else {
            PartitionScheme::Dirichlet { alpha: rng.gen_f64(0.1, 5.0) }
        };
        let p = partition(&ds, clients, scheme, seed);
        let mut all: Vec<usize> = p.client_indices.concat();
        all.sort_unstable();
        let expect: Vec<usize> = (0..n).collect();
        assert_eq!(all, expect, "seed {seed}: not a disjoint cover");
    });
}

#[test]
fn prop_patch_shuffle_preserves_values_any_geometry() {
    forall(40, |rng, seed| {
        let b = rng.gen_range(1, 4);
        let h = [4usize, 8, 16][rng.gen_range(0, 3)];
        let w = h;
        let c = rng.gen_range(1, 6);
        let patch = [1usize, 2, 4, 3][rng.gen_range(0, 4)];
        let mut z: Vec<f32> = (0..b * h * w * c).map(|i| i as f32).collect();
        let mut sorted_before = z.clone();
        patch_shuffle(&mut z, &[b, h, w, c], patch, seed);
        let mut sorted_after = z;
        sorted_before.sort_by(f32::total_cmp);
        sorted_after.sort_by(f32::total_cmp);
        assert_eq!(sorted_before, sorted_after, "seed {seed}: values changed");
    });
}

// ---------------------------------------------------------------------
// codec invariants
// ---------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(rng: &mut Rng64, depth: usize) -> json::Json {
        match if depth == 0 { rng.gen_range(0, 4) } else { rng.gen_range(0, 6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.next_f64() < 0.5),
            2 => json::Json::Num((rng.gen_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => json::Json::Str(format!("s{}-\"x\"\n", rng.gen_range(0, 1000))),
            4 => json::Json::Arr(
                (0..rng.gen_range(0, 5)).map(|_| random_json(rng, depth - 1)).collect(),
            ),
            _ => json::Json::Obj(
                (0..rng.gen_range(0, 5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(200, |rng, seed| {
        let doc = random_json(rng, 3);
        let text = doc.to_string_pretty();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(doc, back, "seed {seed}");
    });
}

#[test]
fn prop_rng_gen_range_uniformity() {
    // chi-square-ish sanity: each of 10 buckets within 3x of expectation
    forall(5, |rng, seed| {
        let mut counts = [0usize; 10];
        let n = 20_000;
        for _ in 0..n {
            counts[rng.gen_range(0, 10)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (n / 10 / 2..n / 10 * 2).contains(&c),
                "seed {seed}: bucket {i} count {c} far from {}",
                n / 10
            );
        }
    });
}
