//! Property tests for the dynamic tier scheduler (hand-rolled randomized
//! driver, same idiom as tests/proptests.rs — no proptest crate on this
//! offline testbed).
//!
//! Invariants under test:
//!   * the profiler's client-side estimate is monotone in tier depth for
//!     monotone reference profiles (the cross-tier ratio extrapolation
//!     cannot reorder tiers);
//!   * every participating client always receives a valid tier in
//!     `1..=max_tiers` with finite, T_max-consistent estimates, for
//!     arbitrary observation histories;
//!   * an all-equal-profile fleet yields a uniform assignment;
//!   * T_max is exactly max_k min_m T̂_k(m).

use dtfl::coordinator::{estimate_round_time, schedule, ClientLoad, Profiler, TierProfile};
use dtfl::runtime::Metadata;
use dtfl::simulation::ServerModel;
use dtfl::util::Rng64;

fn tiny_meta() -> Option<Metadata> {
    let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    Metadata::load(&d).ok()
}

/// Drive `prop` over `cases` seeded random cases.
fn forall(cases: u64, mut prop: impl FnMut(&mut Rng64, u64)) {
    for seed in 0..cases {
        let mut rng = Rng64::seed_from_u64(0x5c4ed ^ seed);
        prop(&mut rng, seed);
    }
}

/// Random reference profile with strictly increasing client-side per-batch
/// times and strictly decreasing server-side times (the shape startup
/// profiling produces — deeper tiers run more layers on the client).
fn monotone_profile(rng: &mut Rng64, tiers: usize) -> TierProfile {
    let mut client = Vec::with_capacity(tiers);
    let mut server = Vec::with_capacity(tiers);
    let mut c = rng.gen_f64(0.01, 0.2);
    let mut s = rng.gen_f64(1.0, 3.0);
    for _ in 0..tiers {
        client.push(c);
        server.push(s);
        c += rng.gen_f64(0.01, 0.3);
        s = (s - rng.gen_f64(0.01, 0.3)).max(1e-3);
    }
    TierProfile { client_batch_secs: client, server_batch_secs: server }
}

fn server() -> ServerModel {
    ServerModel { speedup: 8.0, parallel_factor: 4.0 }
}

#[test]
fn prop_client_estimate_monotone_in_tier_depth() {
    let Some(meta) = tiny_meta() else { return };
    let tiers = meta.max_tiers;
    forall(200, |rng, seed| {
        let profile = monotone_profile(rng, tiers);
        let mut prof = Profiler::new(profile.clone(), 3, rng.gen_f64(0.1, 1.0));
        // client 0: unobserved (pure reference profile). client 1: observed
        // once in a random tier (arbitrary speed — one observation pins the
        // whole curve through the ratio extrapolation). client 2: several
        // observations in random tiers, all consistent with ONE speed
        // factor ("fixed profile": the client is f× the reference host).
        prof.observe(1, rng.gen_range(1, tiers + 1), rng.gen_f64(0.001, 50.0), 1e6);
        let f = rng.gen_f64(0.05, 40.0);
        for _ in 0..5 {
            let t = rng.gen_range(1, tiers + 1);
            prof.observe(2, t, f * profile.client_batch_secs[t - 1], 1e6);
        }
        for k in 0..3 {
            let est: Vec<f64> = (1..=tiers).map(|m| prof.estimate_client_batch(k, m)).collect();
            for w in est.windows(2) {
                assert!(
                    w[1] >= w[0] - 1e-12,
                    "seed {seed}, client {k}: client estimate not monotone: {est:?}"
                );
            }
        }
    });
}

#[test]
fn prop_every_client_gets_a_valid_tier() {
    let Some(meta) = tiny_meta() else { return };
    let tiers = meta.max_tiers;
    forall(200, |rng, seed| {
        let k = rng.gen_range(1, 12);
        let profile = monotone_profile(rng, tiers);
        let mut prof = Profiler::new(profile, k, 0.5);
        for i in 0..k {
            // arbitrary histories, including extreme speeds and links
            if rng.next_f64() < 0.8 {
                prof.observe(
                    i,
                    rng.gen_range(1, tiers + 1),
                    rng.gen_f64(1e-5, 500.0),
                    rng.gen_f64(1e3, 1e9),
                );
            }
        }
        let loads: Vec<ClientLoad> = (0..k)
            .map(|_| ClientLoad {
                n_batches: rng.gen_range(0, 9),
                participating: rng.next_f64() < 0.9,
            })
            .collect();
        let max_tiers = rng.gen_range(1, tiers + 1);
        let s = schedule(&meta, &prof, &server(), &loads, max_tiers);
        s.validate(max_tiers).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let participants = loads.iter().filter(|l| l.participating).count();
        assert_eq!(s.assignments.len(), participants, "seed {seed}");
    });
}

#[test]
fn prop_equal_profiles_yield_uniform_assignment() {
    let Some(meta) = tiny_meta() else { return };
    let tiers = meta.max_tiers;
    forall(100, |rng, seed| {
        let k = rng.gen_range(2, 10);
        let profile = monotone_profile(rng, tiers);
        let mut prof = Profiler::new(profile, k, 0.5);
        // every client observed identically: same tier, same speed, same link
        let obs_tier = rng.gen_range(1, tiers + 1);
        let secs = rng.gen_f64(0.01, 5.0);
        let nu = rng.gen_f64(1e5, 1e8);
        for i in 0..k {
            prof.observe(i, obs_tier, secs, nu);
        }
        let nb = rng.gen_range(1, 6);
        let loads = vec![ClientLoad { n_batches: nb, participating: true }; k];
        let s = schedule(&meta, &prof, &server(), &loads, tiers);
        let t0 = s.tier_of(0);
        for a in &s.assignments {
            assert_eq!(a.tier, t0, "seed {seed}: equal fleet split tiers: {:?}", s.assignments);
        }
    });
}

#[test]
fn prop_tmax_is_max_over_clients_of_min_over_tiers() {
    let Some(meta) = tiny_meta() else { return };
    let tiers = meta.max_tiers;
    forall(100, |rng, seed| {
        let k = rng.gen_range(1, 8);
        let profile = monotone_profile(rng, tiers);
        let mut prof = Profiler::new(profile, k, 0.5);
        for i in 0..k {
            prof.observe(i, rng.gen_range(1, tiers + 1), rng.gen_f64(0.001, 20.0), 1e6);
        }
        let nb = rng.gen_range(1, 5);
        let loads = vec![ClientLoad { n_batches: nb, participating: true }; k];
        let s = schedule(&meta, &prof, &server(), &loads, tiers);
        let srv = server();
        let expect = (0..k)
            .map(|ki| {
                (1..=tiers)
                    .map(|m| estimate_round_time(&meta, &prof, &srv, ki, m, nb))
                    .fold(f64::INFINITY, f64::min)
            })
            .fold(0.0, f64::max);
        assert!(
            (s.t_max - expect).abs() <= 1e-9 * expect.max(1.0),
            "seed {seed}: t_max {} != max-min {}",
            s.t_max,
            expect
        );
    });
}
