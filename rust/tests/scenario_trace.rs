//! Golden scenario trace: the determinism contract extended to scenario
//! mode.
//!
//! With a scenario active — churn, seeded link drift, deadlines, and
//! delta-compressed downlink all exercised at once — every engine
//! configuration in the `{threads, intra_threads, pipeline_depth,
//! agg_shards, fuse_forward, simd}` grid must reproduce the sequential barrier
//! engine's trace **byte for byte**, including the scenario-specific
//! channels (per-round wire bytes and straggler sets). The scenario is
//! constructed so the straggler pattern is *guaranteed* (one cohort's link
//! is slow enough that no tier assignment can beat the deadline), so the
//! test also asserts the semantics carry real signal: churn changes the
//! participant count and the dead-slow cohort is dropped every round it
//! attends.
//!
//! The CI determinism matrix injects extra thread counts per leg via
//! `DTFL_TEST_THREADS` (1/2/8) and forces an uplink codec via
//! `DTFL_TEST_UPLINK`, exactly like `tests/golden_trace.rs`.

use dtfl::coordinator::UplinkCodec;
use dtfl::experiment::Experiment;
use dtfl::harness::{RunSpec, FLASH_CROWD_TOML};
use dtfl::metrics::RoundRecord;
use dtfl::runtime::{simd, SimdLevel};
use dtfl::simulation::{CohortSpec, DeadlinePolicy, LinkEventSpec, Scenario};

/// One round of the trace, everything reduced to exact bit patterns.
#[derive(Debug, Clone, PartialEq, Eq)]
struct TraceRow {
    round: usize,
    sim_time: u64,
    makespan: u64,
    train_loss: u64,
    test_accuracy: Option<u64>,
    tiers: Vec<usize>,
    wire_bytes: u64,
    /// Post-codec uplink bytes: the codec's byte accounting is part of
    /// the scenario determinism contract too.
    up_wire_bytes: u64,
    straggled: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Trace {
    rows: Vec<TraceRow>,
    params: Vec<u32>,
}

fn trace_of(records: &[RoundRecord], params: &[f32]) -> Trace {
    Trace {
        rows: records
            .iter()
            .map(|r| TraceRow {
                round: r.round,
                sim_time: r.sim_time.to_bits(),
                makespan: r.makespan.to_bits(),
                train_loss: r.train_loss.to_bits(),
                test_accuracy: r.test_accuracy.map(f64::to_bits),
                tiers: r.tiers.clone(),
                wire_bytes: r.wire_bytes,
                up_wire_bytes: r.up_wire_bytes,
                straggled: r.straggled,
            })
            .collect(),
        params: params.iter().map(|p| p.to_bits()).collect(),
    }
}

/// Churn + drift + deadline + delta downlink, with a *guaranteed* straggler
/// pattern: the "crowd" cohort's 0.02 Mbps link cannot move any tier's
/// transfer inside the 2 s deadline (the smallest per-tier payload of the
/// tiny artifact is tens of KB ⇒ > 4 s on the wire), while the "core"
/// cohort stays far under it even through the jam window.
fn drop_scenario() -> Scenario {
    let mut core = CohortSpec::new("core", 4, 1.0, 30.0);
    core.walk_sigma = 0.1;
    core.latency_ms = 5.0;
    core.floor_mbps = 10.0;
    let mut crowd = CohortSpec::new("crowd", 2, 0.25, 0.02);
    crowd.arrive = 1;
    crowd.depart = Some(4);
    crowd.data_start = 0.5;
    crowd.data_growth = 0.5;
    crowd.floor_mbps = 0.01;
    crowd.latency_ms = 50.0;
    let jam = LinkEventSpec {
        name: "jam".into(),
        cohort: Some("core".into()),
        from: 2,
        until: 3,
        mbps_scale: 0.5,
        add_latency_ms: 10.0,
    };
    Scenario {
        name: "golden-drop".into(),
        seed: 7,
        deadline_secs: Some(2.0),
        on_deadline: DeadlinePolicy::Drop,
        delta_downlink: true,
        cohorts: vec![core, crowd],
        links: vec![jam],
    }
}

/// Engine configuration under test (`simd: None` = `[run] simd = "auto"`).
#[derive(Debug, Clone, Copy)]
struct Knobs {
    threads: usize,
    intra: usize,
    depth: usize,
    shards: usize,
    fuse: bool,
    simd: Option<SimdLevel>,
}

const REFERENCE: Knobs = Knobs {
    threads: 1,
    intra: 1,
    depth: 1,
    shards: 1,
    fuse: false,
    simd: Some(SimdLevel::Scalar),
};

fn run(method: &str, scenario: Scenario, rounds: usize, k: Knobs) -> Trace {
    let spec = RunSpec {
        method: method.into(),
        clients: scenario.total_clients(),
        rounds,
        batch_cap: Some(1),
        train_total: 96,
        test_total: 32,
        eval_every: 1,
        threads: k.threads,
        intra_threads: k.intra,
        pipeline_depth: k.depth,
        agg_shards: k.shards,
        fuse_forward: k.fuse,
        simd: k.simd.map_or_else(|| "auto".into(), |l| l.name().into()),
        uplink: env_uplink(),
        scenario: Some(scenario),
        ..Default::default()
    };
    let mut exp = Experiment::new(spec.to_config()).expect("scenario experiment");
    let mut records = Vec::new();
    exp.run_with(|r| records.push(r.clone())).expect("scenario run");
    trace_of(&records, exp.method.global_params())
}

/// Extra thread count injected by the CI determinism matrix.
fn env_threads() -> Option<usize> {
    std::env::var("DTFL_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
}

/// Uplink codec forced by the CI determinism matrix (`DTFL_TEST_UPLINK`);
/// `raw` when unset. Goldens are recorded under the same codec in-process.
fn env_uplink() -> UplinkCodec {
    std::env::var("DTFL_TEST_UPLINK")
        .ok()
        .filter(|v| !v.is_empty())
        .map(|v| UplinkCodec::from_name(&v).expect("DTFL_TEST_UPLINK"))
        .unwrap_or(UplinkCodec::Raw)
}

/// One grid entry per supported non-scalar dispatch level (heavyweight
/// per-level coverage runs in the CI `DTFL_TEST_SIMD` legs).
fn simd_entries() -> impl Iterator<Item = Knobs> {
    simd::available()
        .into_iter()
        .filter(|&l| l != SimdLevel::Scalar)
        .map(|l| Knobs { threads: 2, intra: 1, depth: 4, shards: 0, fuse: true, simd: Some(l) })
}

fn grid() -> Vec<Knobs> {
    let mut g = vec![
        // fusion alone against the unfused sequential reference
        Knobs { threads: 1, intra: 1, depth: 1, shards: 1, fuse: true, simd: None },
        // pipelining/sharding alone, sequential pool
        Knobs { threads: 1, intra: 1, depth: 4, shards: 3, fuse: false, simd: None },
        // the default engine (parallel pool, pipelined, auto shards, fused)
        Knobs { threads: 4, intra: 1, depth: 4, shards: 0, fuse: true, simd: None },
        // everything composed, including intra-step kernel splits
        Knobs { threads: 4, intra: 2, depth: 8, shards: 2, fuse: true, simd: None },
    ];
    g.extend(simd_entries());
    if let Some(n) = env_threads() {
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: true, simd: None });
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: false, simd: None });
    }
    g
}

fn assert_knob_invariant(method: &str, scenario: &Scenario, rounds: usize) -> Trace {
    let golden = run(method, scenario.clone(), rounds, REFERENCE);
    assert!(!golden.rows.is_empty(), "{method}: empty scenario trace");
    for k in grid() {
        let t = run(method, scenario.clone(), rounds, k);
        assert_eq!(
            golden.rows, t.rows,
            "{method} {k:?}: scenario trace diverged from the sequential barrier engine"
        );
        assert_eq!(golden.params, t.params, "{method} {k:?}: global param bits diverged");
    }
    golden
}

#[test]
fn dtfl_scenario_trace_is_knob_invariant_with_guaranteed_straggles() {
    let sc = drop_scenario();
    let golden = assert_knob_invariant("dtfl", &sc, 5);

    // churn signal: crowd (2 clients) attends rounds 1..=3 only
    let expect_n = [4usize, 6, 6, 6, 4];
    for (r, row) in golden.rows.iter().enumerate() {
        assert_eq!(
            row.tiers.len(),
            expect_n[r],
            "round {r}: participant count must follow the churn schedule"
        );
        assert!(row.wire_bytes > 0, "round {r}: wire bytes must be accounted");
        // deadline signal: exactly the crowd misses, every round it attends
        let expect_straggled = if (1..=3).contains(&r) { 2 } else { 0 };
        assert_eq!(
            row.straggled, expect_straggled,
            "round {r}: the dead-slow cohort must be dropped, and only it"
        );
    }
    // dropped clients are capped at the deadline, which the core cohort
    // never reaches — so crowd rounds' makespans are exactly the deadline
    for r in 1..=3 {
        assert_eq!(
            f64::from_bits(golden.rows[r].makespan),
            2.0,
            "round {r}: makespan must be the deadline (server stops waiting)"
        );
    }
    assert!(f64::from_bits(golden.rows[0].makespan) < 2.0, "round 0 is drop-free");
}

#[test]
fn fedavg_scenario_trace_is_knob_invariant() {
    let sc = drop_scenario();
    let golden = assert_knob_invariant("fedavg", &sc, 4);
    // whole-model baseline under the same scenario: crowd still can't move
    // a ~44 KP model over a 0.02 Mbps link inside 2 s
    assert_eq!(golden.rows[1].straggled, 2);
    assert!(golden.rows.iter().all(|r| r.tiers.is_empty()), "fedavg records no tiers");
}

#[test]
fn wait_policy_keeps_updates_and_full_makespan() {
    let mut sc = drop_scenario();
    sc.on_deadline = DeadlinePolicy::Wait;
    let golden = assert_knob_invariant("dtfl", &sc, 3);
    // stragglers are still marked...
    assert_eq!(golden.rows[1].straggled, 2);
    // ...but the server waits them out: the makespan blows past the
    // deadline instead of being capped at it
    assert!(f64::from_bits(golden.rows[1].makespan) > 2.0);

    // and the kept updates must change training: same scenario under
    // drop vs wait diverges from round 1 on
    let dropped = run("dtfl", drop_scenario(), 3, REFERENCE);
    assert_ne!(
        golden.params, dropped.params,
        "wait must aggregate the straggler updates that drop discards"
    );
}

#[test]
fn committed_flash_crowd_scenario_runs_and_is_knob_invariant() {
    // the committed example/bench scenario parses and holds the same
    // determinism contract (lighter grid — this one runs 10 clients)
    let sc = Scenario::parse(FLASH_CROWD_TOML).expect("committed scenario parses");
    assert_eq!(sc.total_clients(), 10);
    assert!(sc.delta_downlink && sc.deadline_secs.is_some());
    let golden = run("dtfl", sc.clone(), 4, REFERENCE);
    for k in [
        Knobs { threads: 4, intra: 1, depth: 4, shards: 0, fuse: true, simd: None },
        Knobs { threads: 2, intra: 1, depth: 8, shards: 3, fuse: false, simd: None },
    ] {
        let t = run("dtfl", sc.clone(), 4, k);
        assert_eq!(golden.rows, t.rows, "{k:?}: flash-crowd trace diverged");
        assert_eq!(golden.params, t.params, "{k:?}: flash-crowd params diverged");
    }
    // flash cohort arrives at round 3: participant count grows
    assert_eq!(golden.rows[0].tiers.len(), 6);
    assert_eq!(golden.rows[3].tiers.len(), 10);
}

/// Scenario `depart` must evict per-client codec state: a churned-out
/// device keeps neither its downlink delta snapshot (a rejoin re-seeds
/// from a full broadcast instead of diffing against stale bits) nor its
/// top-k error-feedback residual.
#[test]
fn departed_clients_lose_their_codec_state() {
    let run_eviction = |uplink: UplinkCodec, scenario: Scenario, rounds: usize| {
        let spec = RunSpec {
            method: "dtfl".into(),
            clients: scenario.total_clients(),
            rounds,
            batch_cap: Some(1),
            train_total: 96,
            test_total: 32,
            eval_every: 1,
            uplink,
            scenario: Some(scenario),
            ..Default::default()
        };
        let mut exp = Experiment::new(spec.to_config()).expect("scenario experiment");
        exp.run_with(|_| {}).expect("scenario run");
        exp
    };

    // the crowd cohort (clients 4 and 5) departs at round 4 and never
    // rejoins; the core cohort (0..4) is broadcast to every round
    let exp = run_eviction(UplinkCodec::Raw, drop_scenario(), 5);
    for k in 0..4 {
        assert_eq!(exp.delta_has_snapshot(k), Some(true), "core client {k} keeps its snapshot");
    }
    for k in 4..6 {
        assert_eq!(
            exp.delta_has_snapshot(k),
            Some(false),
            "departed crowd client {k} must have its delta snapshot evicted"
        );
    }
    assert_eq!(exp.uplink_has_residual(0), None, "raw uplink holds no session state");

    let exp = run_eviction(UplinkCodec::TopK, drop_scenario(), 5);
    for k in 0..4 {
        assert_eq!(
            exp.uplink_has_residual(k),
            Some(true),
            "core client {k} carries a top-k residual"
        );
    }
    for k in 4..6 {
        assert_eq!(
            exp.uplink_has_residual(k),
            Some(false),
            "departed crowd client {k} must have its top-k residual evicted"
        );
    }

    // flash-crowd regression: late arrivals are *seeded*, not evicted —
    // every client that is active at the horizon keeps a snapshot
    let sc = Scenario::parse(FLASH_CROWD_TOML).expect("committed scenario parses");
    let exp = run_eviction(UplinkCodec::Delta, sc, 4);
    for k in 0..10 {
        assert_eq!(
            exp.delta_has_snapshot(k),
            Some(true),
            "flash-crowd client {k} must be seeded on arrival and kept"
        );
    }
}

/// Cohort-engine regression for the depart sweep: once a cohort's `depart`
/// round passes, no member holds codec state — members that were sampled
/// get their refcounted snapshot and uplink residual evicted, and members
/// that were never sampled never acquired any (lazy materialization), so
/// the assertion holds for the whole cohort regardless of sampling history.
#[test]
fn cohort_depart_evicts_snapshots_and_residuals() {
    let run_cohort = |uplink: UplinkCodec, sample_count: Option<usize>| {
        let scenario = drop_scenario();
        let spec = RunSpec {
            method: "dtfl".into(),
            clients: scenario.total_clients(),
            rounds: 5,
            batch_cap: Some(1),
            train_total: 96,
            test_total: 32,
            eval_every: 1,
            uplink,
            fleet: "cohort".into(),
            sample_count,
            scenario: Some(scenario),
            ..Default::default()
        };
        let mut exp = Experiment::new(spec.to_config()).expect("cohort experiment");
        let mut records = Vec::new();
        exp.run_with(|r| records.push(r.clone())).expect("cohort run");
        (exp, records)
    };

    // full participation: exactly the naive test's expectations hold
    let (exp, records) = run_cohort(UplinkCodec::Raw, None);
    for k in 0..4 {
        assert_eq!(exp.delta_has_snapshot(k), Some(true), "core client {k} keeps its snapshot");
    }
    for k in 4..6 {
        assert_eq!(
            exp.delta_has_snapshot(k),
            Some(false),
            "departed crowd client {k} must have its snapshot evicted"
        );
    }
    let last = records.last().expect("records");
    assert!(last.snapshot_resident_bytes > 0, "resident-bytes gauge must be live");
    assert!(last.cohort_advances >= 1, "cohort engine advances at cohort granularity");

    // sampled participation: Some(false) must hold for the whole departed
    // cohort whether or not a member was ever sampled
    let (exp, _) = run_cohort(UplinkCodec::TopK, Some(3));
    for k in 4..6 {
        assert_eq!(
            exp.delta_has_snapshot(k),
            Some(false),
            "departed crowd client {k}: no snapshot, sampled or not"
        );
        assert_eq!(
            exp.uplink_has_residual(k),
            Some(false),
            "departed crowd client {k}: no top-k residual, sampled or not"
        );
    }
    // the final round runs with only the core cohort active: its 3 sampled
    // participants received that round's broadcast and keep shared snapshots
    let with_snapshot = (0..4).filter(|&k| exp.delta_has_snapshot(k) == Some(true)).count();
    assert!(with_snapshot >= 3, "final-round participants must keep snapshots (got {with_snapshot})");
}

#[test]
fn scenario_off_is_the_legacy_driver() {
    // belt and braces next to tests/golden_trace.rs: the same RunSpec with
    // and without `scenario: None` is literally the same config object
    let spec = RunSpec { clients: 6, rounds: 2, ..Default::default() };
    assert!(spec.to_config().scenario.is_none());
}
