//! Intra-step kernel parallelism determinism contract.
//!
//! This lives in its own test binary on purpose: the `intra_threads` knob is
//! process-wide (`runtime::kernels::set_intra_threads`), and every
//! `Experiment` construction re-applies its config value. In a shared test
//! binary a concurrently-constructed `Experiment` from another `#[test]`
//! could reset the knob to 1 mid-run, which would make these assertions
//! pass without ever exercising the row-panel fork. Here the only
//! experiments in the process are the sequential ones below, so the intra=4
//! run really does fork panels.

use dtfl::experiment::Experiment;
use dtfl::harness::RunSpec;
use dtfl::metrics::RoundRecord;

fn run(threads: usize, intra_threads: usize) -> (Vec<RoundRecord>, Vec<f32>) {
    let spec = RunSpec {
        clients: 6,
        rounds: 2,
        batch_cap: Some(1),
        train_total: 96,
        test_total: 32,
        eval_every: 1,
        threads,
        intra_threads,
        ..Default::default()
    };
    let mut exp = Experiment::new(spec.to_config()).expect("experiment");
    let mut records = Vec::new();
    exp.run_with(|r| records.push(r.clone())).expect("run");
    (records, exp.method.global_params().to_vec())
}

#[test]
fn intra_step_parallel_kernels_match_sequential() {
    // intra-step row-panel parallelism (kernels splitting one matmul over
    // scoped threads) must be bit-invisible: a 1-thread round with intra=4
    // equals a 1-thread round with intra=1, and composing both kinds of
    // parallelism (threads=4, intra=2) changes nothing either
    let (rec_base, p_base) = run(1, 1);
    let mut grid = vec![(1usize, 4usize), (4, 2)];
    // the CI determinism matrix widens the pool-thread axis per leg
    if let Some(n) = std::env::var("DTFL_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n: &usize| n > 0)
    {
        grid.push((n, 2));
    }
    for (threads, intra) in grid {
        let (rec, p) = run(threads, intra);
        assert_eq!(rec_base.len(), rec.len());
        for (a, b) in rec_base.iter().zip(&rec) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "threads={threads} intra={intra}: train_loss differs"
            );
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        }
        assert_eq!(p_base.len(), p.len());
        for (i, (a, b)) in p_base.iter().zip(&p).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "threads={threads} intra={intra}: global param {i} differs"
            );
        }
    }
}
