//! Golden cross-check for the cohort-vectorized fleet engine
//! (`run.fleet = "cohort"`): at small K, where the naive per-client engine
//! is affordable, both engines must produce **byte-identical** round
//! records and global parameter bits — for DTFL and FedAvg on the
//! committed flash-crowd scenario across the {threads, intra, simd} knob
//! grid, under partial participation (where lazy stream materialization
//! and catch-up replay actually engage), and under fault injection (crash
//! / corrupt / flaky uplink), where the replay must consume exactly the
//! draws the naive engine spent.
//!
//! `host_secs` (wall time) and `cohort_advances` (engine-specific by
//! design: the cohort engine advances per cohort, the naive engine per
//! client) are the only `RoundRecord` channels excluded from the
//! comparison. `snapshot_resident_bytes` is included: the
//! content-addressed store must hold the same bytes either way.

use dtfl::experiment::Experiment;
use dtfl::harness::{RunSpec, FLASH_CROWD_TOML};
use dtfl::metrics::RoundRecord;
use dtfl::runtime::{simd, SimdLevel};
use dtfl::simulation::{CohortSpec, DeadlinePolicy, Scenario};

/// One round reduced to exact bit patterns — every record channel except
/// `host_secs` and `cohort_advances`.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Row {
    round: usize,
    sim_time: u64,
    makespan: u64,
    makespan_compute: u64,
    makespan_comm: u64,
    train_loss: u64,
    test_loss: Option<u64>,
    test_accuracy: Option<u64>,
    lr: u32,
    mean_tier: u64,
    tiers: Vec<usize>,
    wire_bytes: u64,
    up_wire_bytes: u64,
    codec: &'static str,
    straggled: usize,
    quarantined: usize,
    retries: usize,
    staleness: u64,
    tier_flushes: usize,
    snapshot_resident_bytes: u64,
}

fn row(r: &RoundRecord) -> Row {
    Row {
        round: r.round,
        sim_time: r.sim_time.to_bits(),
        makespan: r.makespan.to_bits(),
        makespan_compute: r.makespan_compute.to_bits(),
        makespan_comm: r.makespan_comm.to_bits(),
        train_loss: r.train_loss.to_bits(),
        test_loss: r.test_loss.map(f64::to_bits),
        test_accuracy: r.test_accuracy.map(f64::to_bits),
        lr: r.lr.to_bits(),
        mean_tier: r.mean_tier.to_bits(),
        tiers: r.tiers.clone(),
        wire_bytes: r.wire_bytes,
        up_wire_bytes: r.up_wire_bytes,
        codec: r.codec,
        straggled: r.straggled,
        quarantined: r.quarantined,
        retries: r.retries,
        staleness: r.staleness.to_bits(),
        tier_flushes: r.tier_flushes,
        snapshot_resident_bytes: r.snapshot_resident_bytes,
    }
}

#[derive(Debug, Clone, Copy)]
struct Knobs {
    threads: usize,
    intra: usize,
    simd: Option<SimdLevel>,
}

fn run(
    method: &str,
    scenario: Scenario,
    rounds: usize,
    fleet: &str,
    k: Knobs,
    sample_count: Option<usize>,
) -> (Vec<Row>, Vec<u32>) {
    let spec = RunSpec {
        method: method.into(),
        clients: scenario.total_clients(),
        rounds,
        batch_cap: Some(1),
        train_total: 96,
        test_total: 32,
        eval_every: 1,
        threads: k.threads,
        intra_threads: k.intra,
        simd: k.simd.map_or_else(|| "auto".into(), |l| l.name().into()),
        fleet: fleet.into(),
        sample_count,
        scenario: Some(scenario),
        ..Default::default()
    };
    let mut exp = Experiment::new(spec.to_config()).expect("experiment");
    let mut rows = Vec::new();
    exp.run_with(|r| rows.push(row(r))).expect("run");
    let params = exp.method.global_params().iter().map(|p| p.to_bits()).collect();
    (rows, params)
}

/// Extra thread count injected by the CI determinism matrix.
fn env_threads() -> Option<usize> {
    std::env::var("DTFL_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
}

fn grid() -> Vec<Knobs> {
    let mut g = vec![
        Knobs { threads: 1, intra: 1, simd: Some(SimdLevel::Scalar) },
        Knobs { threads: 4, intra: 1, simd: None },
        Knobs { threads: 2, intra: 2, simd: None },
    ];
    g.extend(
        simd::available()
            .into_iter()
            .filter(|&l| l != SimdLevel::Scalar)
            .map(|l| Knobs { threads: 2, intra: 1, simd: Some(l) }),
    );
    if let Some(n) = env_threads() {
        g.push(Knobs { threads: n, intra: 1, simd: None });
    }
    g
}

fn assert_cross_mode(method: &str, scenario: &Scenario, rounds: usize, sample_count: Option<usize>) {
    for k in grid() {
        let (nr, np) = run(method, scenario.clone(), rounds, "naive", k, sample_count);
        let (cr, cp) = run(method, scenario.clone(), rounds, "cohort", k, sample_count);
        assert!(!nr.is_empty(), "{method} {k:?}: empty trace");
        assert_eq!(nr, cr, "{method} {k:?}: cohort trace diverged from the naive engine");
        assert_eq!(np, cp, "{method} {k:?}: global param bits diverged");
    }
}

#[test]
fn flash_crowd_cohort_equals_naive_dtfl() {
    let sc = Scenario::parse(FLASH_CROWD_TOML).expect("committed scenario parses");
    assert_cross_mode("dtfl", &sc, 4, None);
}

#[test]
fn flash_crowd_cohort_equals_naive_fedavg() {
    let sc = Scenario::parse(FLASH_CROWD_TOML).expect("committed scenario parses");
    assert_cross_mode("fedavg", &sc, 4, None);
}

#[test]
fn sampled_participation_cohort_equals_naive() {
    // partial participation is where the cohort engine earns its keep:
    // non-sampled clients advance only as cohort statistics, and a
    // client's first sample triggers per-stream catch-up replay that must
    // land on exactly the state the always-advancing naive engine holds
    let sc = Scenario::parse(FLASH_CROWD_TOML).expect("committed scenario parses");
    assert_cross_mode("dtfl", &sc, 5, Some(4));
}

#[test]
fn faulty_fleet_cohort_equals_naive() {
    // every fault knob at once, plus churn: the fixed per-round fault draw
    // schedule is what makes a skipped round exactly one discarded draw
    let mut churn = CohortSpec::new("churn", 3, 1.0, 20.0);
    churn.arrive = 1;
    churn.depart = Some(4);
    churn.link_fail_prob = 0.2;
    churn.walk_sigma = 0.05;
    let mut flaky = CohortSpec::new("flaky", 3, 0.5, 8.0);
    flaky.crash_prob = 0.3;
    flaky.corrupt_prob = 0.3;
    flaky.link_fail_prob = 0.4;
    flaky.retry_max = 2;
    flaky.walk_sigma = 0.1;
    let sc = Scenario {
        name: "faulty-cross".into(),
        seed: 23,
        deadline_secs: None,
        on_deadline: DeadlinePolicy::Drop,
        delta_downlink: true,
        cohorts: vec![churn, flaky],
        links: Vec::new(),
    };
    assert_cross_mode("dtfl", &sc, 5, None);
    assert_cross_mode("dtfl", &sc, 5, Some(3));
}
