//! Golden fault trace: the determinism contract extended to fault
//! injection and Byzantine-robust aggregation.
//!
//! With crash faults, Byzantine corruption, and flaky retried uplinks all
//! active at once — plus a robust fold on the server — every engine
//! configuration in the `{threads, intra_threads, pipeline_depth,
//! agg_shards, fuse_forward, simd}` grid must reproduce the sequential barrier
//! engine's trace **byte for byte**, including the fault-specific channels
//! (per-round quarantine and retry counts). The inline scenario guarantees
//! the fault signal: a NaN-corrupt cohort is quarantined every round it
//! delivers, and a flaky cohort's failed uplink attempts are charged and
//! re-sent. A second suite shows the robust folds carry real signal: under
//! a sign-flipping cohort, the trimmed mean and median recover train loss
//! the poisoned plain mean loses.
//!
//! The CI determinism matrix injects extra thread counts per leg via
//! `DTFL_TEST_THREADS` (1/2/8), exactly like `tests/golden_trace.rs`.

use dtfl::coordinator::FoldStrategy;
use dtfl::experiment::Experiment;
use dtfl::harness::{RunSpec, BYZANTINE_FLAKY_TOML};
use dtfl::metrics::RoundRecord;
use dtfl::runtime::{simd, SimdLevel};
use dtfl::simulation::{CohortSpec, CorruptMode, DeadlinePolicy, Scenario};

/// One round of the trace, everything reduced to exact bit patterns.
#[derive(Debug, Clone, PartialEq, Eq)]
struct TraceRow {
    round: usize,
    sim_time: u64,
    makespan: u64,
    train_loss: u64,
    test_accuracy: Option<u64>,
    tiers: Vec<usize>,
    wire_bytes: u64,
    straggled: usize,
    quarantined: usize,
    retries: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Trace {
    rows: Vec<TraceRow>,
    params: Vec<u32>,
}

fn trace_of(records: &[RoundRecord], params: &[f32]) -> Trace {
    Trace {
        rows: records
            .iter()
            .map(|r| TraceRow {
                round: r.round,
                sim_time: r.sim_time.to_bits(),
                makespan: r.makespan.to_bits(),
                train_loss: r.train_loss.to_bits(),
                test_accuracy: r.test_accuracy.map(f64::to_bits),
                tiers: r.tiers.clone(),
                wire_bytes: r.wire_bytes,
                straggled: r.straggled,
                quarantined: r.quarantined,
                retries: r.retries,
            })
            .collect(),
        params: params.iter().map(|p| p.to_bits()).collect(),
    }
}

/// Crash + NaN-corruption + flaky retried uplinks, with guaranteed fault
/// signal: the "nasty" client's every update is NaN-poisoned (quarantined
/// whenever it delivers) and the "flaky" client's uplink attempts fail 60%
/// of the time (retries charged; occasionally all attempts fail and the
/// update is lost). Links are fast and the deadline loose, so the fault
/// channels — not deadline drops — drive the trace.
fn fault_scenario() -> Scenario {
    let mut honest = CohortSpec::new("honest", 4, 1.0, 30.0);
    honest.walk_sigma = 0.05;
    honest.latency_ms = 5.0;
    honest.floor_mbps = 10.0;
    let mut nasty = CohortSpec::new("nasty", 1, 1.0, 30.0);
    nasty.corrupt_prob = 1.0;
    nasty.corrupt_mode = CorruptMode::Nan;
    let mut flaky = CohortSpec::new("flaky", 1, 0.5, 12.0);
    flaky.crash_prob = 0.25;
    flaky.link_fail_prob = 0.6;
    flaky.retry_max = 2;
    flaky.retry_backoff_secs = 0.25;
    Scenario {
        name: "golden-faults".into(),
        seed: 13,
        deadline_secs: Some(30.0),
        on_deadline: DeadlinePolicy::Drop,
        delta_downlink: true,
        cohorts: vec![honest, nasty, flaky],
        links: vec![],
    }
}

/// Engine configuration under test (`simd: None` = `[run] simd = "auto"`).
#[derive(Debug, Clone, Copy)]
struct Knobs {
    threads: usize,
    intra: usize,
    depth: usize,
    shards: usize,
    fuse: bool,
    simd: Option<SimdLevel>,
}

const REFERENCE: Knobs = Knobs {
    threads: 1,
    intra: 1,
    depth: 1,
    shards: 1,
    fuse: false,
    simd: Some(SimdLevel::Scalar),
};

fn run(method: &str, scenario: Scenario, rounds: usize, fold: FoldStrategy, k: Knobs) -> Trace {
    let spec = RunSpec {
        method: method.into(),
        clients: scenario.total_clients(),
        rounds,
        batch_cap: Some(1),
        train_total: scenario.total_clients() * 16,
        test_total: 32,
        eval_every: 1,
        threads: k.threads,
        intra_threads: k.intra,
        pipeline_depth: k.depth,
        agg_shards: k.shards,
        fuse_forward: k.fuse,
        fold,
        simd: k.simd.map_or_else(|| "auto".into(), |l| l.name().into()),
        scenario: Some(scenario),
        ..Default::default()
    };
    let mut exp = Experiment::new(spec.to_config()).expect("fault experiment");
    let mut records = Vec::new();
    exp.run_with(|r| records.push(r.clone())).expect("fault run");
    trace_of(&records, exp.method.global_params())
}

/// Extra thread count injected by the CI determinism matrix.
fn env_threads() -> Option<usize> {
    std::env::var("DTFL_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
}

/// One grid entry per supported non-scalar dispatch level (heavyweight
/// per-level coverage runs in the CI `DTFL_TEST_SIMD` legs).
fn simd_entries() -> impl Iterator<Item = Knobs> {
    simd::available()
        .into_iter()
        .filter(|&l| l != SimdLevel::Scalar)
        .map(|l| Knobs { threads: 2, intra: 1, depth: 4, shards: 0, fuse: true, simd: Some(l) })
}

fn grid() -> Vec<Knobs> {
    let mut g = vec![
        // fusion alone against the unfused sequential reference
        Knobs { threads: 1, intra: 1, depth: 1, shards: 1, fuse: true, simd: None },
        // pipelining/sharding alone, sequential pool
        Knobs { threads: 1, intra: 1, depth: 4, shards: 3, fuse: false, simd: None },
        // the default engine (parallel pool, pipelined, auto shards, fused)
        Knobs { threads: 4, intra: 1, depth: 4, shards: 0, fuse: true, simd: None },
        // everything composed, including intra-step kernel splits
        Knobs { threads: 4, intra: 2, depth: 8, shards: 2, fuse: true, simd: None },
    ];
    g.extend(simd_entries());
    if let Some(n) = env_threads() {
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: true, simd: None });
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: false, simd: None });
    }
    g
}

fn assert_knob_invariant(
    method: &str,
    scenario: &Scenario,
    rounds: usize,
    fold: FoldStrategy,
) -> Trace {
    let golden = run(method, scenario.clone(), rounds, fold, REFERENCE);
    assert!(!golden.rows.is_empty(), "{method}: empty fault trace");
    for k in grid() {
        let t = run(method, scenario.clone(), rounds, fold, k);
        assert_eq!(
            golden.rows, t.rows,
            "{method} fold={fold:?} {k:?}: fault trace diverged from the barrier engine"
        );
        assert_eq!(
            golden.params, t.params,
            "{method} fold={fold:?} {k:?}: global param bits diverged"
        );
    }
    golden
}

#[test]
fn dtfl_fault_trace_is_knob_invariant_with_guaranteed_faults() {
    let sc = fault_scenario();
    let golden = assert_knob_invariant("dtfl", &sc, 5, FoldStrategy::Mean);

    // fault signal: the NaN cohort is quarantined every round it delivers,
    // and the flaky cohort's failed attempts are charged as retries
    let quarantined: usize = golden.rows.iter().map(|r| r.quarantined).sum();
    let retries: usize = golden.rows.iter().map(|r| r.retries).sum();
    assert!(quarantined > 0, "the NaN-corrupt cohort must be quarantined at least once");
    assert!(retries > 0, "a 60% flaky uplink must retry at least once in 5 rounds");
    assert!(
        golden.rows.iter().all(|r| r.quarantined <= 1),
        "only the single NaN client can be quarantined per round"
    );
    // quarantine protects the model: every global parameter stays finite
    assert!(
        golden.params.iter().all(|&b| f32::from_bits(b).is_finite()),
        "quarantined NaN updates must never reach the global model"
    );
}

#[test]
fn fedavg_fault_trace_is_knob_invariant_under_a_robust_fold() {
    // the whole-model path (shared by fedavg/fedyogi/splitfed) holds the
    // same contract, with the robust fold engaged to cover its sharded
    // per-coordinate reduction under real fault traffic
    let sc = fault_scenario();
    let golden = assert_knob_invariant("fedavg", &sc, 4, FoldStrategy::TrimmedMean);
    assert!(golden.rows.iter().all(|r| r.tiers.is_empty()), "fedavg records no tiers");
    assert!(
        golden.params.iter().all(|&b| f32::from_bits(b).is_finite()),
        "robust fold + quarantine must keep the global model finite"
    );
}

#[test]
fn committed_byzantine_flaky_scenario_is_knob_invariant() {
    // the committed bench scenario parses and holds the byte-for-byte
    // contract across the grid
    let sc = Scenario::parse(BYZANTINE_FLAKY_TOML).expect("committed scenario parses");
    assert_eq!(sc.total_clients(), 10);
    assert!(sc.delta_downlink && sc.deadline_secs.is_some());
    assert!(
        sc.cohorts.iter().any(|c| c.corrupt_prob > 0.0)
            && sc.cohorts.iter().any(|c| c.link_fail_prob > 0.0),
        "the committed scenario must actually inject faults"
    );
    let golden = assert_knob_invariant("dtfl", &sc, 3, FoldStrategy::Median);
    let retries: usize = golden.rows.iter().map(|r| r.retries).sum();
    assert!(retries > 0, "the flaky cohort must retry at least once in 3 rounds");
}

#[test]
fn trimmed_mean_and_median_recover_loss_a_poisoned_mean_loses() {
    // the committed scenario's Byzantine cohort sign-flips every update it
    // uploads (finite poison: it folds silently into a plain mean, and the
    // honest clients hold the weight majority — the regime robust
    // aggregation promises recovery in). After 8 rounds the plain mean
    // must be training a measurably worse model than either robust fold.
    let sc = Scenario::parse(BYZANTINE_FLAKY_TOML).expect("committed scenario parses");
    let rounds = 8;
    let final_loss = |fold: FoldStrategy| {
        let t = run("fedavg", sc.clone(), rounds, fold, REFERENCE);
        let loss = f64::from_bits(t.rows.last().expect("rounds ran").train_loss);
        assert!(loss.is_finite(), "{fold:?}: train loss must stay finite");
        loss
    };
    let mean = final_loss(FoldStrategy::Mean);
    let trimmed = final_loss(FoldStrategy::TrimmedMean);
    let median = final_loss(FoldStrategy::Median);
    assert!(
        trimmed < mean,
        "trimmed mean must recover loss the poisoned mean loses ({trimmed} vs {mean})"
    );
    assert!(
        median < mean,
        "median must recover loss the poisoned mean loses ({median} vs {mean})"
    );
}

#[test]
fn no_faults_section_means_no_fault_machinery() {
    // a scenario without fault knobs draws no fault RNG streams and its
    // rounds carry no verdicts — the engines see exactly the pre-fault
    // behavior (the existing golden/scenario traces pin the bytes; this
    // pins the mechanism)
    let sc = Scenario {
        name: "clean".into(),
        seed: 5,
        deadline_secs: None,
        on_deadline: DeadlinePolicy::Drop,
        delta_downlink: false,
        cohorts: vec![CohortSpec::new("a", 3, 1.0, 30.0)],
        links: vec![],
    };
    assert!(sc.cohorts.iter().all(|c| !c.has_faults()));
    let mut engine = dtfl::simulation::ScenarioEngine::new(sc).expect("engine");
    let round = engine.begin_round(0);
    assert!(round.faults.is_none(), "no [faults] knobs -> no verdicts drawn");
    for k in 0..3 {
        let v = round.fault(k);
        assert!(!v.crashed && v.corrupt.is_none() && v.uplink_failures == 0 && !v.uplink_lost);
    }
}
