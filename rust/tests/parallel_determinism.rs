//! Parallel-engine determinism contract: for every method, an N-thread
//! round must be **bit-identical** to the 1-thread round under the same
//! seed — same `RoundOutcome` timings, same losses, same global parameters.
//!
//! Also hosts the smoke-sized round-throughput recorder that refreshes
//! `BENCH_hotpath.json` during `cargo test` (the full-size numbers come from
//! `cargo bench --bench micro_hotpath`).


use dtfl::config::ExperimentConfig;
use dtfl::experiment::Experiment;
use dtfl::harness::RunSpec;
use dtfl::metrics::RoundRecord;

fn config(method: &str, threads: usize) -> ExperimentConfig {
    let mut spec = RunSpec {
        method: method.into(),
        clients: 6,
        rounds: 2,
        batch_cap: Some(1),
        train_total: 96,
        test_total: 32,
        eval_every: 1,
        // RunSpec hardcodes timing_noise = 0.05, exercising per-client RNG streams
        threads,
        ..Default::default()
    };
    if method == "static" {
        spec.static_tier = Some(2);
    }
    spec.to_config()
}

fn run(method: &str, threads: usize) -> (Vec<RoundRecord>, Vec<f32>) {
    let mut exp = Experiment::new(config(method, threads)).expect("experiment");
    let mut records = Vec::new();
    exp.run_with(|r| records.push(r.clone())).expect("run");
    (records, exp.method.global_params().to_vec())
}

/// Thread count for the parallel side of the comparison. The CI
/// determinism matrix overrides it via `DTFL_TEST_THREADS`, so
/// scheduling-dependent bugs cannot hide behind one fixed pool size.
/// An override of 1 is ignored — comparing a sequential run to itself
/// would be a tautology — so that matrix leg falls back to 4.
fn parallel_threads() -> usize {
    std::env::var("DTFL_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 1)
        .unwrap_or(4)
}

fn assert_bitwise_equal_runs(method: &str) {
    let (rec1, p1) = run(method, 1);
    let (recn, pn) = run(method, parallel_threads());
    assert_eq!(rec1.len(), recn.len(), "{method}: round counts differ");
    for (a, b) in rec1.iter().zip(&recn) {
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{method}: sim_time differs");
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{method}: makespan differs");
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "{method}: train_loss differs"
        );
        assert_eq!(a.test_loss.map(f64::to_bits), b.test_loss.map(f64::to_bits), "{method}");
        assert_eq!(
            a.test_accuracy.map(f64::to_bits),
            b.test_accuracy.map(f64::to_bits),
            "{method}: accuracy differs"
        );
        assert_eq!(a.mean_tier.to_bits(), b.mean_tier.to_bits(), "{method}: tiers differ");
    }
    assert_eq!(p1.len(), pn.len());
    for (i, (a, b)) in p1.iter().zip(&pn).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{method}: global param {i} differs: {a} vs {b}"
        );
    }
}

#[test]
fn dtfl_parallel_matches_sequential() {
    assert_bitwise_equal_runs("dtfl");
}

#[test]
fn static_tier_parallel_matches_sequential() {
    assert_bitwise_equal_runs("static");
}

#[test]
fn fedavg_parallel_matches_sequential() {
    assert_bitwise_equal_runs("fedavg");
}

#[test]
fn splitfed_parallel_matches_sequential() {
    assert_bitwise_equal_runs("splitfed");
}

#[test]
fn fedyogi_parallel_matches_sequential() {
    assert_bitwise_equal_runs("fedyogi");
}

#[test]
fn fedgkt_parallel_matches_sequential() {
    assert_bitwise_equal_runs("fedgkt");
}

#[test]
fn repeated_runs_are_bit_reproducible() {
    // same seed + same thread count → identical runs (the cost model is
    // deterministic, not wall-clock)
    let (ra, pa) = run("dtfl", 0);
    let (rb, pb) = run("dtfl", 0);
    assert_eq!(pa, pb);
    assert_eq!(ra.len(), rb.len());
    for (a, b) in ra.iter().zip(&rb) {
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
    }
}

/// Smoke-size round-throughput + kernel-throughput recording: refreshes
/// `BENCH_hotpath.json` on every `cargo test` run so the perf trajectory is
/// tracked even where `cargo bench` never runs. Timing is recorded, not
/// asserted (CI machines vary); bit-identity IS asserted.
#[test]
fn bench_round_smoke_writes_hotpath_json() {
    use std::time::Duration;

    use dtfl::harness::{
        kernels_to_json, measure_async_throughput, measure_fleet_scale, measure_fused_throughput,
        measure_kernel_throughput, measure_pipeline_throughput, measure_robustness_throughput,
        measure_round_throughput, measure_scenario_throughput, measure_simd_throughput,
        measure_wire_efficiency,
    };
    use dtfl::runtime::kernels::tune;
    use dtfl::util::bench::{hotpath_report_path, BenchReport};

    let rt = measure_round_throughput(50, 1, 8).expect("round throughput probe");
    assert!(rt.bit_identical, "K=50 parallel round must match sequential bits");

    let pt = measure_pipeline_throughput(50, 1, 8).expect("pipeline throughput probe");
    assert!(pt.bit_identical, "K=50 pipelined round must match barrier-engine bits");

    let ft = measure_fused_throughput(50, 1, 8).expect("fused throughput probe");
    assert!(ft.bit_identical, "K=50 fused round must match unfused bits");

    let st = measure_scenario_throughput(4).expect("scenario throughput probe");
    assert!(st.bit_identical, "delta downlink must not change FedAvg parameter bits");
    assert!(
        st.fedavg_delta_bytes < st.fedavg_full_bytes,
        "delta broadcast must save bytes ({} vs {})",
        st.fedavg_delta_bytes,
        st.fedavg_full_bytes
    );

    let rb = measure_robustness_throughput(50, 4, Duration::from_millis(150))
        .expect("robustness throughput probe");
    assert!(rb.quarantined > 0 || rb.retries > 0, "the committed fault scenario must fire");
    assert!(
        rb.trimmed_final_train_loss.is_finite() && rb.mean_final_train_loss.is_finite(),
        "signflip poison is finite — both folds' losses must be too"
    );

    let (kernels, arena_peak) =
        measure_kernel_throughput(Duration::from_millis(150)).expect("kernel throughput probe");
    assert!(arena_peak > 0, "full_step must exercise the scratch arena");

    // lane-width × (MR, NR) sweep: smoke-budget samples so `nr_sweep` is
    // populated from every cargo-test run, not only `cargo bench`
    let sweep = tune::sweep(256, 64, 64, Duration::from_millis(25));
    assert!(!sweep.is_empty(), "tune sweep must produce samples");
    assert!(
        sweep.iter().any(|s| s.pinned),
        "one sweep sample must be the pinned (MR, NR, simd) triple"
    );

    let sd = measure_simd_throughput(Duration::from_millis(60)).expect("simd throughput probe");
    assert!(sd.bit_identical, "every dispatch level must match scalar bits");

    let at = measure_async_throughput(6).expect("async tiers probe");
    assert!(at.bit_identical, "async event trace must be knob-invariant");
    assert!(
        at.async_sim_secs < at.drop_sim_secs,
        "async makespan ({:.2}s) must beat the sync drop policy ({:.2}s)",
        at.async_sim_secs,
        at.drop_sim_secs
    );

    let we = measure_wire_efficiency(4).expect("wire efficiency probe");
    assert!(
        we.bit_identical,
        "lossless uplink delta must reproduce the raw leg's parameter and loss bits"
    );
    assert!(
        we.delta_up_bytes < we.raw_up_bytes,
        "uplink delta must save bytes ({} vs {})",
        we.delta_up_bytes,
        we.raw_up_bytes
    );
    assert!(
        we.int8_final_loss.is_finite() && we.topk_final_loss.is_finite(),
        "lossy uplink tracks must still train to a finite loss"
    );

    let fs = measure_fleet_scale(&[50, 10_000, 1_000_000], 2).expect("fleet scale probe");
    assert_eq!(fs.legs.len(), 3, "fleet-scale probe must sample every leg");
    for l in &fs.legs {
        assert!(
            l.resident_bytes > 0 && l.resident_bytes <= l.resident_bound_bytes,
            "fleet {}: snapshot residency {} outside (0, {}]",
            l.fleet,
            l.resident_bytes,
            l.resident_bound_bytes
        );
    }

    let mut report = BenchReport::new();
    // keep any full `cargo bench` micro-bench entries already on disk
    report.preserve_entries_from(hotpath_report_path());
    let source = "cargo-test smoke (see benches/micro_hotpath.rs for the full run)";
    report.extra("bench_round", rt.to_json(source));
    report.extra("pipeline", pt.to_json(source));
    report.extra("fused", ft.to_json(&sweep, source));
    report.extra("scenario", st.to_json(source));
    report.extra("robustness", rb.to_json(source));
    report.extra("kernels", kernels_to_json(&kernels, arena_peak, source));
    report.extra("simd", sd.to_json(source));
    report.extra("async_tiers", at.to_json(source));
    report.extra("wire_efficiency", we.to_json(source));
    report.extra("fleet_scale", fs.to_json(source));
    report.write(hotpath_report_path()).expect("write BENCH_hotpath.json");
}
