//! Kernel-conformance suite for the fused forward path.
//!
//! The repo's bit-identity contract says the fused conv→gn→relu pipeline
//! (single-sweep gn(+relu) epilogues, ŷ recomputed from saved stats in the
//! backward pass, 1×1 stride-1 pad-0 im2col elision) must be **bitwise**
//! indistinguishable from the unfused legacy path — not merely close. These
//! tests drive both paths through `refmath::hooks` (the fusion knob passed
//! explicitly, so fused and unfused runs cannot race the process-wide
//! setting) over randomized shapes, including edge tiles where m/n are not
//! multiples of MR/NR, batch = 1, and single-group gn; they also pin the
//! arena-footprint win (strictly fewer bytes AND strictly fewer buffer
//! loans with fusion on) so a silent re-materialization cannot creep back,
//! and check every `kernels::tune` register-tile candidate against the
//! pinned core.

use dtfl::runtime::kernels::{self, tune, Epilogue, MR, NR};
use dtfl::runtime::refmath::hooks;
use dtfl::runtime::{Dims4, Metadata};
use dtfl::util::Rng64;

fn rand_vec(rng: &mut Rng64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gen_f32(-1.5, 1.5)).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}[{i}]: {x} ({:#010x}) vs {y} ({:#010x})",
            x.to_bits(),
            y.to_bits()
        );
    }
}

fn tiny() -> Metadata {
    Metadata::load(std::path::Path::new("artifacts/tiny")).expect("tiny is built in")
}

// ---------------------------------------------------------------------
// gn(+relu) fusion
// ---------------------------------------------------------------------

#[test]
fn prop_gn_fused_matches_unfused_bitwise() {
    let mut rng = Rng64::seed_from_u64(0xf05e);
    // channel counts exercising single-group (c = 1), one-channel-per-group
    // (c = 5 → 5 groups), partial vector widths, and the max 8-group case
    let channels = [1usize, 3, 5, 8, 16, 24];
    for case in 0..40u64 {
        let b = 1 + rng.gen_range(0, 3); // includes batch = 1
        let h = 1 + rng.gen_range(0, 7);
        let w = 1 + rng.gen_range(0, 7);
        let c = channels[rng.gen_range(0, channels.len())];
        let d: Dims4 = [b, h, w, c];
        let n = b * h * w * c;
        let x = rand_vec(&mut rng, n);
        let dout = rand_vec(&mut rng, n);
        let scale = rand_vec(&mut rng, c);
        let bias = rand_vec(&mut rng, c);
        for relu_after in [false, true] {
            let fused = hooks::gn_forward_backward(&scale, &bias, &x, d, &dout, relu_after, true);
            let plain = hooks::gn_forward_backward(&scale, &bias, &x, d, &dout, relu_after, false);
            let tag = format!("case {case} {d:?} relu={relu_after}");
            assert_bits_eq(&fused.out, &plain.out, &format!("{tag}: out"));
            assert_bits_eq(&fused.dx, &plain.dx, &format!("{tag}: dx"));
            assert_bits_eq(&fused.dscale, &plain.dscale, &format!("{tag}: dscale"));
            assert_bits_eq(&fused.dbias, &plain.dbias, &format!("{tag}: dbias"));
        }
    }
}

// ---------------------------------------------------------------------
// 1×1 im2col elision
// ---------------------------------------------------------------------

#[test]
fn prop_conv1x1_elision_matches_im2col_bitwise() {
    let mut rng = Rng64::seed_from_u64(0xe11d);
    // rows = b·h·w and cout chosen around MR/NR multiples so both full and
    // edge tiles are exercised; includes batch = 1
    let couts = [1usize, 5, NR - 1, NR, NR + 1, 2 * NR + 3];
    for case in 0..40u64 {
        let b = 1 + rng.gen_range(0, 3);
        let h = 1 + rng.gen_range(0, 6);
        let w = 1 + rng.gen_range(0, 6);
        let cin = 1 + rng.gen_range(0, 24);
        let cout = couts[rng.gen_range(0, couts.len())];
        let xd: Dims4 = [b, h, w, cin];
        let x = rand_vec(&mut rng, b * h * w * cin);
        let wgt = rand_vec(&mut rng, cin * cout);
        let dout = rand_vec(&mut rng, b * h * w * cout);
        let elided = hooks::conv_forward_backward(&wgt, &x, xd, 1, 1, cout, 1, 0, &dout, true);
        let im2col = hooks::conv_forward_backward(&wgt, &x, xd, 1, 1, cout, 1, 0, &dout, false);
        let tag = format!("case {case} {xd:?} cout={cout}");
        assert_eq!(elided.od, im2col.od, "{tag}: output dims");
        assert_eq!(elided.macs, im2col.macs, "{tag}: MAC count");
        assert_bits_eq(&elided.out, &im2col.out, &format!("{tag}: out"));
        assert_bits_eq(&elided.dw, &im2col.dw, &format!("{tag}: dw"));
        assert_bits_eq(&elided.dx, &im2col.dx, &format!("{tag}: dx"));
        // the elision must actually drop the column buffers, not just match
        assert!(
            elided.arena_peak < im2col.arena_peak,
            "{tag}: elided peak {} !< im2col peak {}",
            elided.arena_peak,
            im2col.arena_peak
        );
    }
}

#[test]
fn conv_non_elidable_geometries_unchanged_by_fuse() {
    // 3×3 convs and strided 1×1 convs must take the im2col path under
    // either knob setting — and therefore match bitwise trivially
    let mut rng = Rng64::seed_from_u64(0x3e3);
    for &(kh, kw, stride, pad) in &[(3usize, 3usize, 1usize, 1usize), (1, 1, 2, 0), (3, 3, 2, 1)] {
        let (b, h, w, cin, cout) = (2usize, 8usize, 8usize, 6usize, 9usize);
        let xd: Dims4 = [b, h, w, cin];
        let x = rand_vec(&mut rng, b * h * w * cin);
        let wgt = rand_vec(&mut rng, kh * kw * cin * cout);
        let ho = (h + 2 * pad - kh) / stride + 1;
        let wo = (w + 2 * pad - kw) / stride + 1;
        let dout = rand_vec(&mut rng, b * ho * wo * cout);
        let on = hooks::conv_forward_backward(&wgt, &x, xd, kh, kw, cout, stride, pad, &dout, true);
        let off =
            hooks::conv_forward_backward(&wgt, &x, xd, kh, kw, cout, stride, pad, &dout, false);
        let tag = format!("k=({kh},{kw}) s={stride} p={pad}");
        assert_bits_eq(&on.out, &off.out, &format!("{tag}: out"));
        assert_bits_eq(&on.dw, &off.dw, &format!("{tag}: dw"));
        assert_bits_eq(&on.dx, &off.dx, &format!("{tag}: dx"));
    }
}

// ---------------------------------------------------------------------
// whole-model fused == unfused, and the arena-footprint contract
// ---------------------------------------------------------------------

fn det_dout(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng64::seed_from_u64(seed);
    (0..n).map(|_| rng.gen_f32(-0.1, 0.1)).collect()
}

#[test]
fn full_model_fused_matches_unfused_bitwise_and_shrinks_arena() {
    let meta = tiny();
    let p = dtfl::runtime::spec::init_flat(&meta, 3);
    let b = meta.batch;
    let xd: Dims4 = [b, meta.image_hw, meta.image_hw, meta.in_channels];
    let mut rng = Rng64::seed_from_u64(7);
    let x = rand_vec(&mut rng, xd.iter().product());
    let dout = det_dout(b * meta.num_classes, 11);
    let fused = hooks::run_range(&meta, &p, &x, xd, 1, 8, &dout, true).unwrap();
    let plain = hooks::run_range(&meta, &p, &x, xd, 1, 8, &dout, false).unwrap();
    assert_eq!(fused.out_dims, plain.out_dims);
    assert_eq!(fused.macs, plain.macs, "fusion must not change the cost model");
    assert_bits_eq(&fused.out, &plain.out, "full model: logits");
    assert_bits_eq(&fused.grads, &plain.grads, "full model: grads");
    assert!(
        fused.arena_peak < plain.arena_peak,
        "fused full-model peak {} !< unfused {}",
        fused.arena_peak,
        plain.arena_peak
    );
    assert!(
        fused.arena_loans < plain.arena_loans,
        "fused full-model loans {} !< unfused {}",
        fused.arena_loans,
        plain.arena_loans
    );
}

#[test]
fn residual_block_arena_peak_strictly_decreases_with_fusion() {
    // md2 of resnet56 is the width-jump stage: b0 carries a 1×1 stride-1
    // proj shortcut (elided) and every block carries two fused gn sweeps —
    // the dropped ŷ materializations and column buffers must show up as a
    // strictly smaller arena footprint, or something silently
    // re-materialized
    let meta = Metadata::load(std::path::Path::new("artifacts/resnet56")).expect("built-in");
    let flat = dtfl::runtime::spec::init_flat(&meta, 1);
    // module 2's parameter range in the flat layout
    let p = &flat[meta.module_offsets[1]..meta.module_offsets[2]];
    let xd: Dims4 = [1, meta.image_hw, meta.image_hw, meta.widths[0]];
    let mut rng = Rng64::seed_from_u64(21);
    let x = rand_vec(&mut rng, xd.iter().product());
    let dout = det_dout(meta.image_hw * meta.image_hw * meta.widths[1], 5);
    let fused = hooks::run_range(&meta, p, &x, xd, 2, 2, &dout, true).unwrap();
    let plain = hooks::run_range(&meta, p, &x, xd, 2, 2, &dout, false).unwrap();
    assert_bits_eq(&fused.out, &plain.out, "md2: out");
    assert_bits_eq(&fused.grads, &plain.grads, "md2: grads");
    assert!(
        fused.arena_peak < plain.arena_peak,
        "residual block: fused peak {} !< unfused peak {}",
        fused.arena_peak,
        plain.arena_peak
    );
    assert!(
        fused.arena_loans < plain.arena_loans,
        "residual block: fused loans {} !< unfused loans {}",
        fused.arena_loans,
        plain.arena_loans
    );
}

#[test]
fn stride1_proj_elision_fires_in_the_real_model() {
    // resnet56 md1..md2 at batch 1: the md2.b0 proj (16 → 64, stride 1) is
    // the paper model's elidable shortcut; the fused run must take it.
    // Counts come from the run's own forward caches (RangeOut), not the
    // process-wide monotonic counters, so concurrent tests cannot mask a
    // regression here.
    let meta = Metadata::load(std::path::Path::new("artifacts/resnet56")).expect("built-in");
    let flat = dtfl::runtime::spec::init_flat(&meta, 0);
    let p = &flat[..meta.module_offsets[2]];
    let xd: Dims4 = [1, meta.image_hw, meta.image_hw, meta.in_channels];
    let mut rng = Rng64::seed_from_u64(9);
    let x = rand_vec(&mut rng, xd.iter().product());
    let dout = det_dout(meta.image_hw * meta.image_hw * meta.widths[1], 3);
    let (gn_before, el_before) = dtfl::runtime::refmath::fusion_counters();
    let fused = hooks::run_range(&meta, p, &x, xd, 1, 2, &dout, true).unwrap();
    // exactly one elidable conv in md1..md2: the b0 width-jump proj; every
    // normalizer (stem gn + 3 blocks × {gn1, gn2} + b0 gnp) runs fused
    assert_eq!(fused.elided_convs, 1, "stride-1 proj must take the elided path");
    assert_eq!(fused.fused_gn, 1 + 3 * 2 + 1, "all md1..md2 normalizers must fuse");
    let plain = hooks::run_range(&meta, p, &x, xd, 1, 2, &dout, false).unwrap();
    assert_eq!(plain.elided_convs, 0, "unfused run must not elide");
    assert_eq!(plain.fused_gn, 0, "unfused run must not fuse gn");
    assert_bits_eq(&fused.out, &plain.out, "md1..md2: out");
    assert_bits_eq(&fused.grads, &plain.grads, "md1..md2: grads");
    // the process-wide RuntimeStats counters are monotonic, so they must
    // have advanced by at least this run's own counts (other threads can
    // only add)
    let (gn_after, el_after) = dtfl::runtime::refmath::fusion_counters();
    assert!(el_after >= el_before + fused.elided_convs as u64);
    assert!(gn_after >= gn_before + fused.fused_gn as u64);
}

// ---------------------------------------------------------------------
// epilogue hooks across all three matmul orientations
// ---------------------------------------------------------------------

#[test]
fn epilogues_bitwise_match_unfused_passes_in_all_orientations() {
    let mut rng = Rng64::seed_from_u64(0xe91);
    for &(m, k, n) in &[(3usize, 5usize, 7usize), (MR, 9, NR), (MR + 1, 4, NR + 1), (17, 33, 19)] {
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let atn = rand_vec(&mut rng, m * k); // A for tn: (m, k) → C is (k, n)
        let btn = rand_vec(&mut rng, m * n);
        let ant = rand_vec(&mut rng, m * n); // A for nt: (m, n) → C is (m, k)
        let bnt = rand_vec(&mut rng, k * n);
        let scale_n = rand_vec(&mut rng, n);
        let bias_n = rand_vec(&mut rng, n);
        let scale_k = rand_vec(&mut rng, k);
        let bias_k = rand_vec(&mut rng, k);
        let mut macs = 0u64;

        // plain orientation
        let base = kernels::matmul(&a, m, k, &b, n, &mut macs);
        let mut got = vec![0.0f32; m * n];
        kernels::matmul_into(&mut got, &a, m, k, &b, n, Epilogue::Relu, &mut macs);
        let want: Vec<f32> = base.iter().map(|v| v.max(0.0)).collect();
        assert_bits_eq(&got, &want, &format!("matmul relu {m}x{k}x{n}"));
        kernels::matmul_into(
            &mut got,
            &a,
            m,
            k,
            &b,
            n,
            Epilogue::ScaleBiasRelu { scale: &scale_n, bias: &bias_n },
            &mut macs,
        );
        let want: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(i, v)| (v * scale_n[i % n] + bias_n[i % n]).max(0.0))
            .collect();
        assert_bits_eq(&got, &want, &format!("matmul sbr {m}x{k}x{n}"));

        // tn orientation: C is (k, n)
        let base = kernels::matmul_tn(&atn, m, k, &btn, n, &mut macs);
        let mut got = vec![0.0f32; k * n];
        kernels::matmul_tn_into(&mut got, &atn, m, k, &btn, n, Epilogue::Relu, &mut macs);
        let want: Vec<f32> = base.iter().map(|v| v.max(0.0)).collect();
        assert_bits_eq(&got, &want, &format!("matmul_tn relu {m}x{k}x{n}"));
        kernels::matmul_tn_into(
            &mut got,
            &atn,
            m,
            k,
            &btn,
            n,
            Epilogue::ScaleBiasRelu { scale: &scale_n, bias: &bias_n },
            &mut macs,
        );
        let want: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(i, v)| (v * scale_n[i % n] + bias_n[i % n]).max(0.0))
            .collect();
        assert_bits_eq(&got, &want, &format!("matmul_tn sbr {m}x{k}x{n}"));

        // nt orientation: C is (m, k) — per-column vectors have length k
        let base = kernels::matmul_nt(&ant, m, n, &bnt, k, &mut macs);
        let mut got = vec![0.0f32; m * k];
        kernels::matmul_nt_into(&mut got, &ant, m, n, &bnt, k, Epilogue::Relu, &mut macs);
        let want: Vec<f32> = base.iter().map(|v| v.max(0.0)).collect();
        assert_bits_eq(&got, &want, &format!("matmul_nt relu {m}x{n}x{k}"));
        kernels::matmul_nt_into(
            &mut got,
            &ant,
            m,
            n,
            &bnt,
            k,
            Epilogue::ScaleBiasRelu { scale: &scale_k, bias: &bias_k },
            &mut macs,
        );
        let want: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(i, v)| (v * scale_k[i % k] + bias_k[i % k]).max(0.0))
            .collect();
        assert_bits_eq(&got, &want, &format!("matmul_nt sbr {m}x{n}x{k}"));
    }
}

// ---------------------------------------------------------------------
// tune candidates vs the pinned core
// ---------------------------------------------------------------------

#[test]
fn tune_candidates_are_bit_identical_to_pinned_core() {
    // per-element accumulation runs over k in ascending order whatever the
    // register tile or lane width, so every (candidate × dispatch level)
    // must reproduce the pinned core exactly — retuning can never change
    // results
    use dtfl::runtime::simd;
    let mut rng = Rng64::seed_from_u64(0x70e);
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (MR - 1, 5, NR - 1),
        (2 * MR + 3, 17, 2 * NR + 5),
        (33, 40, 29),
    ] {
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut macs = 0u64;
        let pinned = kernels::matmul(&a, m, k, &b, n, &mut macs);
        for lv in simd::available() {
            for &(mr, nr) in tune::CANDIDATES {
                let got =
                    tune::matmul_with(mr, nr, lv, &a, m, k, &b, n).expect("listed candidate");
                let what = format!("tile ({mr},{nr}) simd={} at {m}x{k}x{n}", lv.name());
                assert_bits_eq(&got, &pinned, &what);
            }
            assert!(tune::matmul_with(7, 13, lv, &a, m, k, &b, n).is_none());
        }
        assert!(
            tune::CANDIDATES.contains(&(MR, NR)),
            "the pinned (MR, NR) must stay in the sweep grid"
        );
    }
}
