//! Golden-trace suite: end-to-end guards on the DTFL training dynamics.
//!
//! For a small deterministic run of DTFL and every baseline we record a
//! compact trace — per-round makespan/sim-time/loss/accuracy bits, the
//! tier assignments, and a checksum plus the full bit pattern of the final
//! global parameters — from the **sequential barrier engine** (1 thread,
//! `pipeline_depth` 1, `agg_shards` 1, intra off, `fuse_forward` off,
//! `simd` forced to `scalar` — i.e. the legacy unfused scalar math). Every
//! other engine configuration in the
//! `{threads, intra_threads, pipeline_depth, agg_shards, fuse_forward,
//! simd}` grid must reproduce that trace **byte for byte**: the pipelined
//! round engine, the sharded aggregation flush, the double-buffered
//! snapshot swap, next-round input prefetch, the fused gn/relu forward
//! path, the 1×1 im2col elision, and every SIMD dispatch level are all
//! required to be bit-invisible.
//!
//! The reference trace is recorded in-process (float bit patterns are only
//! stable per libm build, so a committed file would be flaky across
//! machines); the DTFL trace is additionally written to
//! `GOLDEN_trace.json` at the repo root for inspection, next to
//! `BENCH_hotpath.json`.
//!
//! The CI determinism matrix injects an extra thread count per leg via
//! `DTFL_TEST_THREADS` (1/2/8), forces dispatch levels via
//! `DTFL_TEST_SIMD` (flows through every `simd: None` = "auto" entry), and
//! forces an uplink codec via `DTFL_TEST_UPLINK` — the whole grid reruns
//! under that codec, so its byte accounting and (for lossy codecs) its
//! transformed training dynamics must be knob-invariant too.

use dtfl::coordinator::UplinkCodec;
use dtfl::experiment::Experiment;
use dtfl::harness::RunSpec;
use dtfl::metrics::RoundRecord;
use dtfl::runtime::{simd, SimdLevel};
use dtfl::util::json::{self, Json};

/// One round of the trace, everything reduced to exact bit patterns.
#[derive(Debug, Clone, PartialEq, Eq)]
struct TraceRow {
    round: usize,
    sim_time: u64,
    makespan: u64,
    makespan_compute: u64,
    makespan_comm: u64,
    train_loss: u64,
    test_loss: Option<u64>,
    test_accuracy: Option<u64>,
    lr: u32,
    tiers: Vec<usize>,
    /// Post-codec uplink bytes — the wire accounting is part of the
    /// determinism contract (must not drift with engine knobs).
    up_wire_bytes: u64,
}

#[derive(Debug, Clone, PartialEq)]
struct Trace {
    rows: Vec<TraceRow>,
    /// Final global parameters, exact bits.
    params: Vec<u32>,
    /// FNV-1a over `params` (the compact fingerprint recorded in the JSON).
    checksum: u64,
}

fn checksum(params: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for p in params {
        for b in p.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

fn trace_of(records: &[RoundRecord], params: &[f32]) -> Trace {
    let rows = records
        .iter()
        .map(|r| TraceRow {
            round: r.round,
            sim_time: r.sim_time.to_bits(),
            makespan: r.makespan.to_bits(),
            makespan_compute: r.makespan_compute.to_bits(),
            makespan_comm: r.makespan_comm.to_bits(),
            train_loss: r.train_loss.to_bits(),
            test_loss: r.test_loss.map(f64::to_bits),
            test_accuracy: r.test_accuracy.map(f64::to_bits),
            lr: r.lr.to_bits(),
            tiers: r.tiers.clone(),
            up_wire_bytes: r.up_wire_bytes,
        })
        .collect();
    let params: Vec<u32> = params.iter().map(|p| p.to_bits()).collect();
    let checksum = checksum(&params);
    Trace { rows, params, checksum }
}

/// Engine configuration under test. `simd: None` means `[run] simd =
/// "auto"` (runtime detection + the `DTFL_TEST_SIMD` override); `Some`
/// forces one dispatch level.
#[derive(Debug, Clone, Copy)]
struct Knobs {
    threads: usize,
    intra: usize,
    depth: usize,
    shards: usize,
    fuse: bool,
    simd: Option<SimdLevel>,
}

const REFERENCE: Knobs = Knobs {
    threads: 1,
    intra: 1,
    depth: 1,
    shards: 1,
    fuse: false,
    simd: Some(SimdLevel::Scalar),
};

fn run(method: &str, k: Knobs) -> Trace {
    run_with_uplink(method, k, env_uplink())
}

fn run_with_uplink(method: &str, k: Knobs, uplink: UplinkCodec) -> Trace {
    let mut spec = RunSpec {
        method: method.into(),
        clients: 6,
        rounds: 3,
        batch_cap: Some(1),
        train_total: 96,
        test_total: 32,
        eval_every: 1,
        threads: k.threads,
        intra_threads: k.intra,
        pipeline_depth: k.depth,
        agg_shards: k.shards,
        fuse_forward: k.fuse,
        simd: k.simd.map_or_else(|| "auto".into(), |l| l.name().into()),
        uplink,
        ..Default::default()
    };
    if method == "static" {
        spec.static_tier = Some(2);
    }
    let mut exp = Experiment::new(spec.to_config()).expect("experiment");
    let mut records = Vec::new();
    exp.run_with(|r| records.push(r.clone())).expect("run");
    trace_of(&records, exp.method.global_params())
}

/// Extra thread count injected by the CI determinism matrix.
fn env_threads() -> Option<usize> {
    std::env::var("DTFL_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
}

/// Uplink codec forced by the CI determinism matrix (`DTFL_TEST_UPLINK`);
/// `raw` when unset. The in-process golden is recorded under the same
/// codec, so a forced leg checks that codec's knob-invariance end to end.
fn env_uplink() -> UplinkCodec {
    std::env::var("DTFL_TEST_UPLINK")
        .ok()
        .filter(|v| !v.is_empty())
        .map(|v| UplinkCodec::from_name(&v).expect("DTFL_TEST_UPLINK"))
        .unwrap_or(UplinkCodec::Raw)
}

fn assert_trace_matches(method: &str, golden: &Trace, k: Knobs) {
    let t = run(method, k);
    assert_eq!(
        golden.rows, t.rows,
        "{method} {k:?}: per-round trace diverged from the sequential barrier engine"
    );
    assert_eq!(
        golden.checksum, t.checksum,
        "{method} {k:?}: global-param checksum diverged"
    );
    assert_eq!(golden.params, t.params, "{method} {k:?}: global param bits diverged");
}

/// One grid entry per supported non-scalar dispatch level, everything else
/// at the default engine settings — the heavyweight per-level coverage
/// runs in the CI `DTFL_TEST_SIMD` legs through the "auto" entries.
fn simd_entries() -> impl Iterator<Item = Knobs> {
    simd::available()
        .into_iter()
        .filter(|&l| l != SimdLevel::Scalar)
        .map(|l| Knobs { threads: 2, intra: 1, depth: 4, shards: 0, fuse: true, simd: Some(l) })
}

/// The grid every method is checked against (DTFL gets a larger one).
fn small_grid() -> Vec<Knobs> {
    let mut g = vec![
        // fusion alone against the unfused sequential reference
        Knobs { threads: 1, intra: 1, depth: 1, shards: 1, fuse: true, simd: None },
        // the default engine (fused) with the parallel pool
        Knobs { threads: 4, intra: 1, depth: 4, shards: 0, fuse: true, simd: None },
        // pipelined + sharded with fusion off
        Knobs { threads: 2, intra: 1, depth: 8, shards: 3, fuse: false, simd: None },
    ];
    g.extend(simd_entries());
    if let Some(n) = env_threads() {
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: true, simd: None });
    }
    g
}

fn dtfl_grid() -> Vec<Knobs> {
    let mut g = vec![
        // fusion alone, sequential barrier pool
        Knobs { threads: 1, intra: 1, depth: 1, shards: 1, fuse: true, simd: None },
        // pipelining/sharding alone, sequential pool, unfused
        Knobs { threads: 1, intra: 1, depth: 4, shards: 3, fuse: false, simd: None },
        // deep pipeline: every flat fold deferred to the finish flush
        Knobs { threads: 1, intra: 1, depth: 64, shards: 0, fuse: true, simd: None },
        // parallel pool with the barrier aggregator, unfused
        Knobs { threads: 2, intra: 1, depth: 1, shards: 1, fuse: false, simd: None },
        // parallel + pipelined + auto shards + fusion (the default engine)
        Knobs { threads: 4, intra: 1, depth: 4, shards: 0, fuse: true, simd: None },
        // everything composed, including intra-step kernel splits
        Knobs { threads: 4, intra: 2, depth: 8, shards: 2, fuse: true, simd: None },
    ];
    g.extend(simd_entries());
    if let Some(n) = env_threads() {
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: true, simd: None });
        g.push(Knobs { threads: n, intra: 1, depth: 4, shards: 0, fuse: false, simd: None });
    }
    g
}

fn assert_method_golden(method: &str, grid: &[Knobs]) -> Trace {
    let golden = run(method, REFERENCE);
    assert!(!golden.rows.is_empty(), "{method}: empty trace");
    for &k in grid {
        assert_trace_matches(method, &golden, k);
    }
    golden
}

#[test]
fn dtfl_golden_trace_is_knob_invariant() {
    let golden = assert_method_golden("dtfl", &dtfl_grid());
    // tier assignments are part of the trace — make sure they carry signal
    assert!(
        golden.rows.iter().all(|r| !r.tiers.is_empty()),
        "DTFL trace must record tier assignments"
    );
    write_golden_json("dtfl", &golden);
}

#[test]
fn static_tier_golden_trace_is_knob_invariant() {
    let golden = assert_method_golden("static", &small_grid());
    assert!(golden.rows.iter().all(|r| r.tiers.iter().all(|&t| t == 2)));
}

#[test]
fn fedavg_golden_trace_is_knob_invariant() {
    assert_method_golden("fedavg", &small_grid());
}

#[test]
fn splitfed_golden_trace_is_knob_invariant() {
    assert_method_golden("splitfed", &small_grid());
}

#[test]
fn fedyogi_golden_trace_is_knob_invariant() {
    assert_method_golden("fedyogi", &small_grid());
}

#[test]
fn fedgkt_golden_trace_is_knob_invariant() {
    assert_method_golden("fedgkt", &small_grid());
}

/// Rows with the byte-accounting column blanked, for cross-codec
/// comparisons (a lossless codec changes `up_wire_bytes` and nothing else).
fn rows_sans_up_bytes(t: &Trace) -> Vec<TraceRow> {
    t.rows
        .iter()
        .cloned()
        .map(|mut r| {
            r.up_wire_bytes = 0;
            r
        })
        .collect()
}

fn up_total(t: &Trace) -> u64 {
    t.rows.iter().map(|r| r.up_wire_bytes).sum()
}

/// The lossless contract, stated directly: a `delta`-uplink run must
/// reproduce the raw run's trace and final parameter bits exactly, with
/// strictly fewer uplink bytes — on the tiered methods and the
/// whole-model baselines alike.
#[test]
fn lossless_uplink_delta_is_bit_invisible_and_saves_bytes() {
    for method in ["dtfl", "fedavg", "splitfed"] {
        let raw = run_with_uplink(method, REFERENCE, UplinkCodec::Raw);
        let delta = run_with_uplink(method, REFERENCE, UplinkCodec::Delta);
        assert_eq!(
            rows_sans_up_bytes(&raw),
            rows_sans_up_bytes(&delta),
            "{method}: the lossless delta codec may only change byte accounting"
        );
        assert_eq!(raw.params, delta.params, "{method}: delta codec perturbed training bits");
        let (raw_up, delta_up) = (up_total(&raw), up_total(&delta));
        assert!(raw_up > 0, "{method}: uplink bytes must be accounted");
        assert!(
            delta_up < raw_up,
            "{method}: uplink delta must save bytes ({delta_up} vs {raw_up})"
        );
    }
}

/// The lossy codecs get their own goldens: their (intentionally
/// different) training dynamics must still be bit-identical across
/// engine knobs, and smallest-wins caps them at the raw accounting.
#[test]
fn lossy_uplink_codecs_are_knob_invariant_with_their_own_goldens() {
    let light = [
        Knobs { threads: 4, intra: 1, depth: 4, shards: 0, fuse: true, simd: None },
        Knobs { threads: 2, intra: 1, depth: 8, shards: 3, fuse: false, simd: None },
    ];
    let raw_up = up_total(&run_with_uplink("dtfl", REFERENCE, UplinkCodec::Raw));
    for codec in [UplinkCodec::Int8, UplinkCodec::TopK] {
        let golden = run_with_uplink("dtfl", REFERENCE, codec);
        assert!(
            golden.rows.iter().all(|r| f64::from_bits(r.train_loss).is_finite()),
            "{}: lossy training must stay finite",
            codec.name()
        );
        for k in light {
            let t = run_with_uplink("dtfl", k, codec);
            assert_eq!(
                golden.rows,
                t.rows,
                "{} {k:?}: lossy uplink trace diverged across engine knobs",
                codec.name()
            );
            assert_eq!(
                golden.params,
                t.params,
                "{} {k:?}: lossy uplink param bits diverged",
                codec.name()
            );
        }
        assert!(
            up_total(&golden) <= raw_up,
            "{}: smallest-wins must cap the codec at the raw accounting",
            codec.name()
        );
    }
}

/// Record the DTFL golden trace next to BENCH_hotpath.json (diagnostics —
/// bit patterns are hex so diffs between machines/toolchains are obvious).
fn write_golden_json(method: &str, t: &Trace) {
    let rows: Vec<Json> = t
        .rows
        .iter()
        .map(|r| {
            json::obj(vec![
                ("round", json::num(r.round as f64)),
                ("sim_time_bits", json::s(format!("{:016x}", r.sim_time))),
                ("makespan_bits", json::s(format!("{:016x}", r.makespan))),
                ("train_loss_bits", json::s(format!("{:016x}", r.train_loss))),
                (
                    "test_accuracy_bits",
                    r.test_accuracy
                        .map(|b| json::s(format!("{b:016x}")))
                        .unwrap_or(Json::Null),
                ),
                (
                    "tiers",
                    Json::Arr(r.tiers.iter().map(|&t| json::num(t as f64)).collect()),
                ),
            ])
        })
        .collect();
    let doc = json::obj(vec![
        ("method", json::s(method)),
        ("rounds", Json::Arr(rows)),
        ("params", json::num(t.params.len() as f64)),
        ("param_checksum_fnv1a", json::s(format!("{:016x}", t.checksum))),
        (
            "note",
            json::s("recorded per-machine by tests/golden_trace.rs; engines are compared in-process"),
        ),
    ]);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../GOLDEN_trace.json");
    if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
        eprintln!("warning: could not write {}: {e}", path.display());
    }
}
