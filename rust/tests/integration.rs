//! Integration tests: runtime + coordinator + every federated method,
//! end-to-end against the `tiny` config.
//!
//! These are the consumer-side contract checks of the step-function
//! interface (the python side is covered by python/tests/test_aot.py).
//! Under the default reference backend the `tiny` artifact set needs no
//! files on disk — metadata and initial parameters are synthesized — so
//! these tests always run; with `--features pjrt` and `make artifacts`
//! they exercise the PJRT path instead.

use std::path::PathBuf;

use dtfl::config::ExperimentConfig;
use dtfl::coordinator::{load_initial_model, profile_tiers, Dtfl, DtflOptions};
use dtfl::data::{generate_train, DatasetSpec};
use dtfl::experiment::Experiment;
use dtfl::runtime::{literal as lit, Runtime, StepEngine, TrainState};

fn artifacts() -> Option<PathBuf> {
    // always available: the reference backend synthesizes missing artifacts
    Some(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny"))
}

fn runtime() -> Option<Runtime> {
    artifacts().map(|d| Runtime::open(d).expect("open tiny artifacts"))
}

fn config(method: &str) -> String {
    format!(
        r#"
        [model]
        artifact = "tiny"
        artifacts_dir = "{root}/artifacts"
        [data]
        spec = "tiny"
        train_total = 96
        test_total = 48
        [clients]
        count = 3
        seed = 5
        [run]
        method = "{method}"
        rounds = 2
        batch_cap = 1
        max_tiers = 2
        eval_every = 1
        timing_noise = 0.0
        "#,
        root = env!("CARGO_MANIFEST_DIR"),
        method = method
    )
}

fn run_method(method: &str) -> dtfl::metrics::RunReport {
    let mut text = config(method);
    if method == "static" {
        text += "\n[run]\nstatic_tier = 2\n";
        // mini-TOML merges repeated sections, so this just adds the key —
        // but to keep one [run] block, patch the original text instead:
        text = config(method).replace("max_tiers = 2", "max_tiers = 2\n        static_tier = 2");
    }
    let cfg = ExperimentConfig::parse(&text).unwrap();
    let mut exp = Experiment::new(cfg).unwrap();
    exp.run().unwrap()
}

// ---------------------------------------------------------------------
// runtime-level contract
// ---------------------------------------------------------------------

#[test]
fn eval_artifact_executes_with_sane_initial_loss() {
    let Some(rt) = runtime() else { return };
    let engine = StepEngine::new(&rt);
    let m = &rt.meta;
    let global = load_initial_model(&rt).unwrap();

    let n = m.eval_batch * m.image_hw * m.image_hw * m.in_channels;
    let x = lit::f32_literal(&vec![0.5; n], &[m.eval_batch, m.image_hw, m.image_hw, 3]).unwrap();
    let y = lit::i32_vec(&vec![0i32; m.eval_batch]).unwrap();
    let (loss, correct) = engine.eval_batch(&global.flat, &x, &y).unwrap();
    // untrained model on a constant image: CE should be in a loose band
    // around ln(10) = 2.30 (random aux/fc heads skew it upward)
    assert!((1.0..7.0).contains(&loss), "init loss {loss}");
    assert!((0.0..=m.eval_batch as f32).contains(&correct));
}

#[test]
fn artifact_execution_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let engine = StepEngine::new(&rt);
    let m = &rt.meta;
    let global = load_initial_model(&rt).unwrap();
    let n = m.batch * m.image_hw * m.image_hw * m.in_channels;
    let x = lit::f32_literal(&vec![0.25; n], &[m.batch, m.image_hw, m.image_hw, 3]).unwrap();
    let y = lit::i32_vec(&(0..m.batch as i32).map(|i| i % 10).collect::<Vec<_>>()).unwrap();

    let run = || {
        let mut st = TrainState::new(global.client_vec(m, 1));
        let out = engine.client_step(1, &mut st, 1e-3, &x, &y, None).unwrap();
        (st.params, out.loss)
    };
    let (p1, l1) = run();
    let (p2, l2) = run();
    assert_eq!(l1, l2, "loss must be bit-deterministic");
    assert_eq!(p1, p2, "updated params must be bit-deterministic");
}

#[test]
fn client_server_steps_compose_across_all_tiers() {
    let Some(rt) = runtime() else { return };
    let engine = StepEngine::new(&rt);
    let m = &rt.meta;
    let global = load_initial_model(&rt).unwrap();
    let n = m.batch * m.image_hw * m.image_hw * m.in_channels;
    let x = lit::f32_literal(&vec![0.5; n], &[m.batch, m.image_hw, m.image_hw, 3]).unwrap();
    let y = lit::i32_vec(&(0..m.batch as i32).map(|i| i % 10).collect::<Vec<_>>()).unwrap();

    // exercise tiers 1 and max (the extreme splits)
    for tier in [1, m.max_tiers] {
        let mut cs = TrainState::new(global.client_vec(m, tier));
        let cout = engine.client_step(tier, &mut cs, 1e-3, &x, &y, None).unwrap();
        assert!(cout.loss.is_finite());
        assert_eq!(
            cout.z.element_count(),
            m.tier(tier).z_shape.iter().product::<usize>()
        );
        let mut ss = TrainState::new(global.server_vec(m, tier));
        let sout = engine.server_step(tier, &mut ss, 1e-3, &cout.z, &y).unwrap();
        assert!(sout.loss.is_finite());
        // adam step counters advanced on both sides
        assert_eq!(cs.t, 2.0);
        assert_eq!(ss.t, 2.0);
    }
}

#[test]
fn dcor_artifact_runs_and_alpha_matters() {
    let Some(rt) = runtime() else { return };
    if !rt.meta.has_dcor {
        return;
    }
    let engine = StepEngine::new(&rt);
    let m = &rt.meta;
    let global = load_initial_model(&rt).unwrap();
    let ds = generate_train(&DatasetSpec::tiny(m.batch, 8));
    let idx: Vec<usize> = (0..m.batch).collect();
    let b = dtfl::data::Batcher::new(&ds, &idx, m.batch).batch(0).unwrap();

    let mut s0 = TrainState::new(global.client_vec(m, 1));
    let o0 = engine.client_step(1, &mut s0, 1e-3, &b.x, &b.y, Some(0.0)).unwrap();
    let mut s1 = TrainState::new(global.client_vec(m, 1));
    let o1 = engine.client_step(1, &mut s1, 1e-3, &b.x, &b.y, Some(0.75)).unwrap();
    assert!(o0.loss.is_finite() && o1.loss.is_finite());
    assert_ne!(o0.loss, o1.loss, "alpha must change the objective");
}

#[test]
fn tier_profile_is_monotone_in_the_expected_direction() {
    let Some(rt) = runtime() else { return };
    let global = load_initial_model(&rt).unwrap();
    let prof = profile_tiers(&rt, &global, rt.meta.max_tiers).unwrap();
    // client-side model grows with tier => client time should trend up;
    // allow jitter by comparing the extremes (Table 2's shape).
    assert!(
        prof.client_batch_secs[rt.meta.max_tiers - 1] > prof.client_batch_secs[0],
        "client time should grow from tier 1 to {}: {:?}",
        rt.meta.max_tiers,
        prof.client_batch_secs
    );
    assert!(
        prof.server_batch_secs[rt.meta.max_tiers - 1] < prof.server_batch_secs[0],
        "server time should shrink: {:?}",
        prof.server_batch_secs
    );
}

// ---------------------------------------------------------------------
// method-level end-to-end (2 rounds each, tiny)
// ---------------------------------------------------------------------

#[test]
fn dtfl_end_to_end() {
    if artifacts().is_none() {
        return;
    }
    let rep = run_method("dtfl");
    assert_eq!(rep.rounds_run, 2);
    assert!(rep.total_sim_time > 0.0);
    assert!(rep.final_accuracy >= 0.0 && rep.final_accuracy <= 1.0);
}

#[test]
fn static_tier_end_to_end() {
    if artifacts().is_none() {
        return;
    }
    let rep = run_method("static");
    assert_eq!(rep.method, "static-tier");
    assert_eq!(rep.rounds_run, 2);
}

#[test]
fn fedavg_end_to_end() {
    if artifacts().is_none() {
        return;
    }
    let rep = run_method("fedavg");
    assert_eq!(rep.rounds_run, 2);
    assert!(rep.total_sim_time > 0.0);
}

#[test]
fn splitfed_end_to_end() {
    if artifacts().is_none() {
        return;
    }
    let rep = run_method("splitfed");
    assert!(rep.total_sim_time > 0.0);
}

#[test]
fn fedyogi_end_to_end() {
    if artifacts().is_none() {
        return;
    }
    let rep = run_method("fedyogi");
    assert!(rep.total_sim_time > 0.0);
}

#[test]
fn fedgkt_end_to_end() {
    if artifacts().is_none() {
        return;
    }
    let rep = run_method("fedgkt");
    assert!(rep.total_sim_time > 0.0);
}

#[test]
fn privacy_pipeline_end_to_end() {
    if artifacts().is_none() {
        return;
    }
    let text = config("dtfl") + "\n[privacy]\ndcor_alpha = 0.25\npatch_shuffle = 4\n";
    let cfg = ExperimentConfig::parse(&text).unwrap();
    let mut exp = Experiment::new(cfg).unwrap();
    let rep = exp.run().unwrap();
    assert_eq!(rep.rounds_run, 2);
}

#[test]
fn dtfl_assigns_slow_clients_lower_tiers_over_time() {
    let Some(rt) = runtime() else { return };
    // construct DTFL directly and feed it synthetic observations through
    // the profiler, then check the schedule ordering matches speed ordering
    let opts = DtflOptions {
        max_tiers: rt.meta.max_tiers,
        ema_beta: 1.0,
        timing_noise: 0.0,
        static_tier: None,
    };
    let mut dtfl = Dtfl::new(&rt, 2, opts).unwrap();
    let base = dtfl.profiler.profile.client_batch_secs[0];
    dtfl.profiler.observe(0, 1, base * 50.0, 30e6 / 8.0); // very slow client
    dtfl.profiler.observe(1, 1, base / 4.0, 100e6 / 8.0); // fast client
    let server = dtfl::simulation::ServerModel::default();
    let loads = vec![
        dtfl::coordinator::ClientLoad { n_batches: 4, participating: true };
        2
    ];
    let s =
        dtfl::coordinator::schedule(&rt.meta, &dtfl.profiler, &server, &loads, rt.meta.max_tiers);
    assert!(s.tier_of(0) <= s.tier_of(1), "slow client must not out-tier fast one");
}

#[test]
fn pipelined_empty_round_carries_global_over() {
    // regression: simulation/clock.rs logs + counts empty-participant
    // rounds, but nothing exercised a *pipelined* empty round end-to-end —
    // the engine must carry the global snapshot over unchanged (no
    // aggregation, no snapshot swap) instead of erroring in finish.
    use dtfl::coordinator::parallel::resolve_threads;
    use dtfl::data::{self, BatchCache, PartitionScheme};
    use dtfl::fed::{Method, PrivacyCfg, RoundEnv};
    use dtfl::simulation::{ServerModel, VirtualClock};

    let Some(rt) = runtime() else { return };
    let opts = DtflOptions { max_tiers: 2, ema_beta: 0.5, timing_noise: 0.0, static_tier: None };
    let mut dtfl = Dtfl::new(&rt, 3, opts).unwrap();
    let before = dtfl.global_params().to_vec();

    let train = generate_train(&DatasetSpec::tiny(96, 8));
    let partition = data::partition(&train, 3, PartitionScheme::Iid, 5);
    let batches = BatchCache::new(&partition, rt.meta.batch);
    let profiles = vec![dtfl::simulation::ResourceProfile::new(1.0, 30.0); 3];
    let next = vec![0usize, 2];
    let mut env = RoundEnv {
        rt: &rt,
        train: &train,
        partition: &partition,
        batches: &batches,
        profiles: &profiles,
        participants: &[], // nobody sampled this round
        server: ServerModel::default(),
        lr: 1e-3,
        round: 4,
        batch_cap: Some(1),
        privacy: PrivacyCfg::default(),
        seed: 5,
        threads: resolve_threads(0).min(4),
        pipeline_depth: 4, // pipelined engine: prefetch + buffered flush on
        agg_shards: 0,
        next_participants: Some(&next),
        scenario: None,
        downlink: None,
        fold: dtfl::coordinator::FoldStrategy::Mean,
        uplink: None,
        prox_mu: 0.0,
    };
    let out = dtfl.round(&mut env).unwrap();
    assert!(out.times.is_empty() && out.tiers.is_empty());
    assert_eq!(out.train_loss, 0.0);
    assert_eq!(
        dtfl.global_params(),
        &before[..],
        "empty round must carry the global model over bit-for-bit"
    );
    // next-round inputs were still prefetched during the empty round
    assert!(batches.encoded() > 0, "prefetch must warm the batch cache");

    // the virtual clock counts the round (with the round index in its log)
    // without moving time
    let mut clock = VirtualClock::new();
    assert_eq!(clock.advance_round(&out.times), 0.0);
    assert_eq!(clock.rounds(), 1, "empty round must still count");
    assert_eq!(clock.now(), 0.0, "empty round must not move the clock");
}

#[test]
fn aggregation_round_trip_via_single_client() {
    if artifacts().is_none() {
        return;
    }
    // with exactly one client, the aggregated global must equal the
    // client's reconstituted halves bit-for-bit
    let text = config("dtfl").replace("count = 3", "count = 1");
    let cfg = ExperimentConfig::parse(&text).unwrap();
    let mut exp = Experiment::new(cfg).unwrap();
    let rep = exp.run().unwrap();
    assert_eq!(rep.rounds_run, 2);
    let params = exp.method.global_params();
    assert!(params.iter().all(|v| v.is_finite()));
}
