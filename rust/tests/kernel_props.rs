//! Property tests for the blocked kernel layer: randomized shapes —
//! including edge tiles where M/N/K are not multiples of the register-tile
//! sizes — compared against independent scalar references written here
//! (f64 accumulation, textbook loop order), plus structural invariants
//! (im2col/col2im adjointness, intra-thread bit-identity).

use dtfl::runtime::kernels::{self, Epilogue, MR, NR};
use dtfl::runtime::Dims4;
use dtfl::util::Rng64;

fn rand_vec(rng: &mut Rng64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gen_f32(-1.5, 1.5)).collect()
}

/// |got − want| ≤ atol + rtol·|want| elementwise, with f64 references.
fn assert_close(got: &[f32], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let err = (g as f64 - w).abs();
        let tol = 1e-4 + 1e-4 * w.abs();
        assert!(err <= tol, "{what}[{i}]: got {g}, want {w} (err {err:.3e})");
    }
}

// ---------------------------------------------------------------------
// independent scalar references (f64 accumulators, textbook order)
// ---------------------------------------------------------------------

fn ref_matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for kk in 0..k {
                acc += a[i * k + kk] as f64 * b[kk * n + j] as f64;
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn ref_matmul_tn(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; k * n];
    for kk in 0..k {
        for j in 0..n {
            let mut acc = 0.0f64;
            for mi in 0..m {
                acc += a[mi * k + kk] as f64 * b[mi * n + j] as f64;
            }
            c[kk * n + j] = acc;
        }
    }
    c
}

fn ref_matmul_nt(a: &[f32], m: usize, n: usize, b: &[f32], k: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; m * k];
    for i in 0..m {
        for kk in 0..k {
            let mut acc = 0.0f64;
            for j in 0..n {
                acc += a[i * n + j] as f64 * b[kk * n + j] as f64;
            }
            c[i * k + kk] = acc;
        }
    }
    c
}

/// Per-element gather formulation of im2col (no early-continue structure).
#[allow(clippy::too_many_arguments)]
fn ref_im2col(x: &[f32], xd: Dims4, kh: usize, kw: usize, stride: usize, pad: usize) -> Vec<f32> {
    let [b, h, w, c] = xd;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w + 2 * pad - kw) / stride + 1;
    let k = kh * kw * c;
    let mut out = vec![0.0f32; b * ho * wo * k];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                for i in 0..kh {
                    for j in 0..kw {
                        for cc in 0..c {
                            let py = oy * stride + i;
                            let px = ox * stride + j;
                            let v = if py >= pad && py < h + pad && px >= pad && px < w + pad {
                                x[((bi * h + (py - pad)) * w + (px - pad)) * c + cc]
                            } else {
                                0.0
                            };
                            let row = ((bi * ho + oy) * wo + ox) * k;
                            out[row + (i * kw + j) * c + cc] = v;
                        }
                    }
                }
            }
        }
    }
    out
}

/// Scatter-add built on the gather reference: for each column element that
/// maps to a real input position, add it there.
fn ref_col2im(
    cols: &[f32],
    xd: Dims4,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> Vec<f32> {
    let [b, h, w, c] = xd;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w + 2 * pad - kw) / stride + 1;
    let k = kh * kw * c;
    let mut dx = vec![0.0f32; b * h * w * c];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                for i in 0..kh {
                    for j in 0..kw {
                        for cc in 0..c {
                            let py = oy * stride + i;
                            let px = ox * stride + j;
                            if py >= pad && py < h + pad && px >= pad && px < w + pad {
                                let row = ((bi * ho + oy) * wo + ox) * k;
                                dx[((bi * h + (py - pad)) * w + (px - pad)) * c + cc] +=
                                    cols[row + (i * kw + j) * c + cc];
                            }
                        }
                    }
                }
            }
        }
    }
    dx
}

// ---------------------------------------------------------------------
// properties
// ---------------------------------------------------------------------

/// Shapes mixing edge-tile cases (±1 around MR/NR multiples) with random
/// sizes, so partial tiles in both dimensions and short/long reductions are
/// all exercised.
fn shapes(rng: &mut Rng64, cases: usize) -> Vec<(usize, usize, usize)> {
    let mut out = vec![
        (1, 1, 1),
        (MR, 3, NR),
        (MR - 1, 5, NR - 1),
        (MR + 1, 7, NR + 1),
        (2 * MR + 3, 2, 2 * NR + 5),
        (3, 200, 3),
    ];
    for _ in 0..cases {
        out.push((rng.gen_range(1, 48), rng.gen_range(1, 96), rng.gen_range(1, 48)));
    }
    out
}

#[test]
fn prop_blocked_matmul_matches_scalar_reference() {
    let mut rng = Rng64::seed_from_u64(0x5eed);
    for (m, k, n) in shapes(&mut rng, 40) {
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut macs = 0u64;
        let got = kernels::matmul(&a, m, k, &b, n, &mut macs);
        assert_eq!(macs, (m * k * n) as u64);
        assert_close(&got, &ref_matmul(&a, m, k, &b, n), &format!("matmul {m}x{k}x{n}"));
    }
}

#[test]
fn prop_blocked_matmul_tn_matches_scalar_reference() {
    let mut rng = Rng64::seed_from_u64(0x7a11);
    for (m, k, n) in shapes(&mut rng, 40) {
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, m * n);
        let mut macs = 0u64;
        let got = kernels::matmul_tn(&a, m, k, &b, n, &mut macs);
        assert_close(&got, &ref_matmul_tn(&a, m, k, &b, n), &format!("tn {m}x{k}x{n}"));
    }
}

#[test]
fn prop_blocked_matmul_nt_matches_scalar_reference() {
    let mut rng = Rng64::seed_from_u64(0xbeef);
    for (m, n, k) in shapes(&mut rng, 40) {
        let a = rand_vec(&mut rng, m * n);
        let b = rand_vec(&mut rng, k * n);
        let mut macs = 0u64;
        let got = kernels::matmul_nt(&a, m, n, &b, k, &mut macs);
        assert_close(&got, &ref_matmul_nt(&a, m, n, &b, k), &format!("nt {m}x{n}x{k}"));
    }
}

#[test]
fn prop_epilogues_match_unfused_reference() {
    let mut rng = Rng64::seed_from_u64(0xfade);
    for (m, k, n) in shapes(&mut rng, 15) {
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);
        let plain = ref_matmul(&a, m, k, &b, n);
        let mut macs = 0u64;
        let with_bias = kernels::matmul_bias(&a, m, k, &b, n, &bias, &mut macs);
        let mut with_relu = vec![0.0f32; m * n];
        kernels::matmul_into(&mut with_relu, &a, m, k, &b, n, Epilogue::BiasRelu(&bias), &mut macs);
        let want_bias: Vec<f64> = plain
            .iter()
            .enumerate()
            .map(|(idx, &v)| v + bias[idx % n] as f64)
            .collect();
        let want_relu: Vec<f64> = want_bias.iter().map(|&v| v.max(0.0)).collect();
        assert_close(&with_bias, &want_bias, &format!("bias {m}x{k}x{n}"));
        assert_close(&with_relu, &want_relu, &format!("bias+relu {m}x{k}x{n}"));
    }
}

#[test]
fn prop_blocked_kernels_are_zero_skip_consistent_on_sparse_data() {
    // post-ReLU activations are ~half zeros; the skip-zero fast path must
    // not change results relative to the dense reference
    let mut rng = Rng64::seed_from_u64(0xaced);
    for (m, k, n) in shapes(&mut rng, 20) {
        let a: Vec<f32> = rand_vec(&mut rng, m * k)
            .into_iter()
            .map(|v| if v < 0.0 { 0.0 } else { v })
            .collect();
        let b = rand_vec(&mut rng, k * n);
        let mut macs = 0u64;
        let got = kernels::matmul(&a, m, k, &b, n, &mut macs);
        assert_close(&got, &ref_matmul(&a, m, k, &b, n), &format!("sparse {m}x{k}x{n}"));
    }
}

#[test]
fn prop_im2col_matches_gather_reference() {
    let mut rng = Rng64::seed_from_u64(0x1217);
    for case in 0..60u64 {
        let b = rng.gen_range(1, 4);
        let h = rng.gen_range(3, 10);
        let w = rng.gen_range(3, 10);
        let c = rng.gen_range(1, 6);
        let kh = 1 + rng.gen_range(0, 3.min(h));
        let kw = 1 + rng.gen_range(0, 3.min(w));
        let stride = 1 + rng.gen_range(0, 2);
        let pad = rng.gen_range(0, 2);
        let xd: Dims4 = [b, h, w, c];
        let x = rand_vec(&mut rng, b * h * w * c);
        let (rows, k, _, _) = kernels::im2col_geom(xd, kh, kw, stride, pad);
        let mut got = vec![0.0f32; rows * k];
        kernels::im2col_into(&mut got, &x, xd, kh, kw, stride, pad);
        let want = ref_im2col(&x, xd, kh, kw, stride, pad);
        assert_eq!(got, want, "case {case}: {xd:?} k=({kh},{kw}) s={stride} p={pad}");
    }
}

#[test]
fn prop_col2im_matches_scatter_reference_and_is_adjoint() {
    let mut rng = Rng64::seed_from_u64(0x90de);
    for case in 0..60u64 {
        let b = rng.gen_range(1, 3);
        let h = rng.gen_range(3, 9);
        let w = rng.gen_range(3, 9);
        let c = rng.gen_range(1, 5);
        let kh = 1 + rng.gen_range(0, 3.min(h));
        let kw = 1 + rng.gen_range(0, 3.min(w));
        let stride = 1 + rng.gen_range(0, 2);
        let pad = rng.gen_range(0, 2);
        let xd: Dims4 = [b, h, w, c];
        let (rows, k, _, _) = kernels::im2col_geom(xd, kh, kw, stride, pad);
        let cols = rand_vec(&mut rng, rows * k);
        let mut got = vec![0.0f32; b * h * w * c];
        kernels::col2im_into(&mut got, &cols, xd, kh, kw, stride, pad);
        let want = ref_col2im(&cols, xd, kh, kw, stride, pad);
        assert_eq!(got, want, "case {case}: {xd:?} k=({kh},{kw}) s={stride} p={pad}");

        // adjointness: ⟨im2col(x), y⟩ = ⟨x, col2im(y)⟩ — im2col and col2im
        // must be exact transposes of each other
        let x = rand_vec(&mut rng, b * h * w * c);
        let mut ix = vec![0.0f32; rows * k];
        kernels::im2col_into(&mut ix, &x, xd, kh, kw, stride, pad);
        let lhs: f64 = ix.iter().zip(&cols).map(|(&p, &q)| p as f64 * q as f64).sum();
        let rhs: f64 = x.iter().zip(&got).map(|(&p, &q)| p as f64 * q as f64).sum();
        assert!(
            (lhs - rhs).abs() <= 1e-6 + 1e-9 * lhs.abs().max(rhs.abs()),
            "case {case}: adjoint identity broken ({lhs} vs {rhs})"
        );
    }
}

#[test]
fn prop_intra_thread_counts_are_bit_identical() {
    // results must not depend on the intra-step split: same bits for 1, 2,
    // 3 and 8 workers, including shapes big enough to clear the fork
    // threshold and shapes with edge panels
    let mut rng = Rng64::seed_from_u64(0xd00d);
    for (m, k, n) in [(130, 70, 130), (257, 33, 129)] {
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let btn = rand_vec(&mut rng, m * n);
        let mut macs = 0u64;
        kernels::set_intra_threads(1);
        let base = kernels::matmul(&a, m, k, &b, n, &mut macs);
        let base_tn = kernels::matmul_tn(&a, m, k, &btn, n, &mut macs);
        for t in [2usize, 3, 8] {
            kernels::set_intra_threads(t);
            let got = kernels::matmul(&a, m, k, &b, n, &mut macs);
            assert_eq!(base, got, "matmul bits differ at intra={t}");
            let got_tn = kernels::matmul_tn(&a, m, k, &btn, n, &mut macs);
            assert_eq!(base_tn, got_tn, "matmul_tn bits differ at intra={t}");
        }
        kernels::set_intra_threads(1);
    }
}
