//! SIMD-dispatch conformance suite.
//!
//! The explicit AVX2/AVX-512/NEON kernel variants in `runtime::simd` are
//! required to be **bitwise** indistinguishable from the scalar core —
//! every vector lane replays the scalar core's pinned per-element
//! reduction order, so switching dispatch levels (or racing the
//! process-wide knob mid-run) can never change a single bit. These tests
//! drive the public APIs the variants sit under — the three packed-panel
//! matmul orientations with every fused epilogue, the fused gn(+relu)
//! sweep, and the sharded aggregation fold — over randomized
//! non-lane-multiple shapes, NaN/±inf/-0.0 payloads, and concurrent
//! runs with a thread hammering `set_simd`.
//!
//! The CI determinism matrix additionally forces whole-suite levels via
//! `DTFL_TEST_SIMD` (scalar / avx2 legs).

use dtfl::coordinator::{fold_updates_sharded, ClientUpdate};
use dtfl::runtime::kernels::{self, Epilogue};
use dtfl::runtime::refmath::hooks;
use dtfl::runtime::{set_simd, simd, Dims4, Metadata, SimdLevel};
use dtfl::util::Rng64;

fn rand_vec(rng: &mut Rng64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gen_f32(-1.5, 1.5)).collect()
}

/// Scatter NaN, ±inf, and signed zeros through a buffer so the special
/// cases flow through the fused epilogues at every lane position.
fn inject_specials(v: &mut [f32]) {
    let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0f32, 0.0f32];
    for (i, x) in v.iter_mut().enumerate() {
        if i % 7 == 3 {
            *x = specials[(i / 7) % specials.len()];
        }
    }
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}[{i}]: {x} ({:#010x}) vs {y} ({:#010x})",
            x.to_bits(),
            y.to_bits()
        );
    }
}

/// Run `f` with the process-wide dispatch level set to `lv`. Another test
/// thread may legitimately flip the level mid-call — the whole point of
/// the contract is that this cannot change the result.
fn with_level<T>(lv: SimdLevel, f: impl FnOnce() -> T) -> T {
    set_simd(lv).expect("available level is supported");
    f()
}

// ---------------------------------------------------------------------
// matmul orientations × epilogues
// ---------------------------------------------------------------------

#[test]
fn matmul_orientations_and_epilogues_match_scalar_across_levels() {
    // shapes chosen so edge tiles and non-lane-multiple columns are hit:
    // n ∈ {3, 27, 29} is never a multiple of 4/8/16, m smaller than MR,
    // and the 1×1×1 degenerate case
    let mut rng = Rng64::seed_from_u64(0x51dc);
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (13, 9, 27), (33, 20, 29)] {
        let mut a = rand_vec(&mut rng, m * k);
        let mut b = rand_vec(&mut rng, k * n);
        inject_specials(&mut a);
        inject_specials(&mut b);
        let bias = rand_vec(&mut rng, n);
        let scale = rand_vec(&mut rng, n);
        let run = |lv: SimdLevel| {
            with_level(lv, || {
                let mut macs = 0u64;
                let mut outs = vec![
                    kernels::matmul(&a, m, k, &b, n, &mut macs),
                    kernels::matmul_tn(&a, k, m, &b, n, &mut macs),
                    kernels::matmul_nt(&a, m, k, &b, n, &mut macs),
                ];
                let eps = [
                    Epilogue::None,
                    Epilogue::Bias(&bias),
                    Epilogue::BiasRelu(&bias),
                    Epilogue::Relu,
                    Epilogue::ScaleBiasRelu { scale: &scale, bias: &bias },
                ];
                for ep in eps {
                    let mut c = vec![0.0f32; m * n];
                    kernels::matmul_into(&mut c, &a, m, k, &b, n, ep, &mut macs);
                    outs.push(c);
                }
                outs
            })
        };
        let scalar = run(SimdLevel::Scalar);
        for lv in simd::available() {
            let got = run(lv);
            for (which, (g, s)) in got.iter().zip(&scalar).enumerate() {
                let what = format!("{m}x{k}x{n} out#{which} simd={}", lv.name());
                assert_bits_eq(g, s, &what);
            }
        }
    }
}

// ---------------------------------------------------------------------
// fused gn(+relu) sweep with special payloads
// ---------------------------------------------------------------------

#[test]
fn fused_gn_propagates_specials_identically_across_levels() {
    // NaN / ±inf poison whole groups through the shared stats; -0.0 and
    // +0.0 must keep their sign bits through normalize and survive (or
    // not) the relu clamp exactly as the scalar core decides
    let mut rng = Rng64::seed_from_u64(0x6e5);
    for &(b, h, w, c) in &[(1usize, 3usize, 5usize, 3usize), (2, 4, 4, 5), (1, 7, 3, 16)] {
        let d: Dims4 = [b, h, w, c];
        let n = b * h * w * c;
        let mut x = rand_vec(&mut rng, n);
        inject_specials(&mut x);
        let dout = rand_vec(&mut rng, n);
        let scale = rand_vec(&mut rng, c);
        let bias = rand_vec(&mut rng, c);
        for relu_after in [false, true] {
            let run = |lv: SimdLevel, fuse: bool| {
                with_level(lv, || {
                    hooks::gn_forward_backward(&scale, &bias, &x, d, &dout, relu_after, fuse)
                })
            };
            let scalar = run(SimdLevel::Scalar, true);
            for lv in simd::available() {
                let got = run(lv, true);
                let tag = format!("{d:?} relu={relu_after} simd={}", lv.name());
                assert_bits_eq(&got.out, &scalar.out, &format!("{tag}: out"));
                assert_bits_eq(&got.dx, &scalar.dx, &format!("{tag}: dx"));
                assert_bits_eq(&got.dscale, &scalar.dscale, &format!("{tag}: dscale"));
                assert_bits_eq(&got.dbias, &scalar.dbias, &format!("{tag}: dbias"));
                // and the fused sweep still matches the unfused legacy
                // path at this level even with specials in flight
                let plain = run(lv, false);
                assert_bits_eq(&got.out, &plain.out, &format!("{tag}: fused vs unfused"));
            }
        }
    }
}

// ---------------------------------------------------------------------
// aggregation folds
// ---------------------------------------------------------------------

#[test]
fn agg_fold_is_identical_across_shards_and_levels() {
    let meta = Metadata::load(std::path::Path::new("artifacts/tiny")).expect("built-in config");
    let mut rng = Rng64::seed_from_u64(0xa66);
    let updates: Vec<ClientUpdate> = (0..7)
        .map(|i| {
            let tier = 1 + i % meta.max_tiers;
            let t = meta.tier(tier);
            let mut client_vec = rand_vec(&mut rng, t.client_vec_len);
            let mut server_vec = rand_vec(&mut rng, t.server_vec_len);
            inject_specials(&mut client_vec);
            inject_specials(&mut server_vec);
            ClientUpdate {
                client_id: i,
                tier,
                weight: 1.0 + i as f32 * 0.25,
                client_vec,
                server_vec,
            }
        })
        .collect();
    let fold = |lv: SimdLevel, shards: usize| {
        with_level(lv, || {
            let mut acc = vec![0.0f32; meta.total_params];
            fold_updates_sharded(&meta, &mut acc, &updates, shards);
            acc
        })
    };
    let reference = fold(SimdLevel::Scalar, 1);
    for lv in simd::available() {
        for shards in [1usize, 2, 3, 5] {
            let got = fold(lv, shards);
            let what = format!("fold shards={shards} simd={}", lv.name());
            assert_bits_eq(&got, &reference, &what);
        }
    }
}

// ---------------------------------------------------------------------
// the process-wide knob under contention
// ---------------------------------------------------------------------

#[test]
fn concurrent_runs_with_racing_level_flips_stay_bit_identical() {
    // `set_simd` is process-wide (like `set_intra_threads`), so two
    // runtimes forcing different levels share one knob. That is safe by
    // construction — every level produces identical bits — and this pins
    // it: workers compute while a flipper hammers the knob, and every
    // result must still equal the scalar reference.
    use std::sync::atomic::{AtomicBool, Ordering};

    let levels = simd::available();
    let (m, k, n) = (33usize, 20usize, 29usize);
    let mut rng = Rng64::seed_from_u64(0xace5);
    let a = rand_vec(&mut rng, m * k);
    let b = rand_vec(&mut rng, k * n);
    let reference = with_level(SimdLevel::Scalar, || {
        let mut macs = 0u64;
        kernels::matmul(&a, m, k, &b, n, &mut macs)
    });

    let stop = AtomicBool::new(false);
    let (a, b, reference) = (&a, &b, &reference);
    std::thread::scope(|s| {
        let flipper = s.spawn(|| {
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                set_simd(levels[i % levels.len()]).expect("available level");
                i += 1;
            }
        });
        let workers: Vec<_> = (0..3)
            .map(|w| {
                s.spawn(move || {
                    let mut macs = 0u64;
                    for it in 0..200 {
                        let got = kernels::matmul(a, m, k, b, n, &mut macs);
                        assert_bits_eq(&got, reference, &format!("worker {w} iter {it}"));
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().expect("worker");
        }
        stop.store(true, Ordering::Relaxed);
        flipper.join().expect("flipper");
    });
}
