//! Ablation bench: the dynamic tier scheduler vs static assignments vs an
//! oracle, on a pure-simulation timing model (no PJRT — runs in ms).
//!
//! Questions answered (the design choices DESIGN.md calls out):
//!   1. How much round-makespan does dynamic re-tiering save over the best
//!      static single tier, across profile pools and timing noise?
//!   2. How close is the profiler's EMA+ratio estimate to an oracle that
//!      knows every client's true speed (scheduler regret)?
//!   3. How does the EMA weight β trade estimate error under noise?
//!
//! Run: `cargo bench --bench ablation_scheduler`

use dtfl::coordinator::{schedule, ClientLoad, Profiler, TierProfile};
use dtfl::runtime::Metadata;
use dtfl::simulation::{ProfilePool, ServerModel};
use dtfl::util::bench::section;
use dtfl::util::Rng64;

/// True per-batch client compute seconds for client k in tier m.
fn true_time(profile_cpus: f64, ref_profile: &TierProfile, m: usize) -> f64 {
    ref_profile.client_batch_secs[m - 1] / profile_cpus
}

/// Simulated round makespan for a tier assignment under the true model.
fn makespan(
    meta: &Metadata,
    ref_profile: &TierProfile,
    cpus: &[f64],
    mbps: &[f64],
    tiers: &[usize],
    nb: usize,
    server: &ServerModel,
) -> f64 {
    tiers
        .iter()
        .enumerate()
        .map(|(k, &m)| {
            let t = meta.tier(m);
            let tc = true_time(cpus[k], ref_profile, m) * nb as f64;
            let bytes = t.model_transfer_bytes as f64 + nb as f64 * t.z_bytes_per_batch as f64;
            let tcom = bytes * 8.0 / (mbps[k] * 1e6);
            let ts = server.secs(ref_profile.server_batch_secs[m - 1]) * nb as f64
                / server.parallel_factor;
            (tc + tcom).max(ts + tcom)
        })
        .fold(0.0, f64::max)
}

/// Oracle: exhaustive best per-client tier given TRUE times (min-max).
fn oracle_tiers(
    meta: &Metadata,
    ref_profile: &TierProfile,
    cpus: &[f64],
    mbps: &[f64],
    nb: usize,
    server: &ServerModel,
) -> Vec<usize> {
    let k = cpus.len();
    let est = |ki: usize, m: usize| {
        makespan(meta, ref_profile, &cpus[ki..ki + 1], &mbps[ki..ki + 1], &[m], nb, server)
    };
    // T_max = max_k min_m, then per-client largest tier under T_max —
    // same policy as the scheduler but with perfect information.
    let tmax = (0..k)
        .map(|ki| (1..=meta.max_tiers).map(|m| est(ki, m)).fold(f64::INFINITY, f64::min))
        .fold(0.0, f64::max);
    (0..k)
        .map(|ki| {
            (1..=meta.max_tiers)
                .rev()
                .find(|&m| est(ki, m) <= tmax + 1e-12)
                .unwrap_or(1)
        })
        .collect()
}

fn main() -> dtfl::anyhow::Result<()> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("metadata.json").exists() {
        eprintln!("tiny artifacts missing; run `make artifacts`");
        return Ok(());
    }
    let meta = Metadata::load(&dir)?;
    // representative reference profile (measured shape: client grows,
    // server shrinks with tier)
    let ref_profile = TierProfile {
        client_batch_secs: vec![0.0013, 0.0058, 0.0100, 0.0124, 0.0147, 0.0172, 0.0191],
        server_batch_secs: vec![0.0204, 0.0163, 0.0089, 0.0192, 0.0026, 0.0012, 0.0002],
    };
    let server = ServerModel::default();
    let nb = 4usize;
    let k = 10usize;

    for pool in [ProfilePool::Paper, ProfilePool::Case1, ProfilePool::Case2] {
        section(&format!("pool = {} (10 clients, 200 rounds, noise 10%)", pool.name()));
        let mut rng = Rng64::seed_from_u64(7);
        let profiles = pool.assign(k, &mut rng);
        let cpus: Vec<f64> = profiles.iter().map(|p| p.cpus).collect();
        let mbps: Vec<f64> = profiles.iter().map(|p| p.mbps).collect();

        // oracle + best-static references
        let oracle = oracle_tiers(&meta, &ref_profile, &cpus, &mbps, nb, &server);
        let t_oracle = makespan(&meta, &ref_profile, &cpus, &mbps, &oracle, nb, &server);
        let (best_static, t_static) = (1..=meta.max_tiers)
            .map(|m| {
                let tiers = vec![m; k];
                (m, makespan(&meta, &ref_profile, &cpus, &mbps, &tiers, nb, &server))
            })
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();

        // dynamic scheduler driven by noisy observations over rounds
        for beta in [0.1, 0.5, 0.9] {
            let mut prof = Profiler::new(ref_profile.clone(), k, beta);
            let loads = vec![ClientLoad { n_batches: nb, participating: true }; k];
            let mut total = 0.0;
            let mut rounds = 0usize;
            let mut tiers: Vec<usize> = vec![1; k];
            for _ in 0..200 {
                let s = schedule(&meta, &prof, &server, &loads, meta.max_tiers);
                for a in &s.assignments {
                    tiers[a.client_id] = a.tier;
                }
                let t = makespan(&meta, &ref_profile, &cpus, &mbps, &tiers, nb, &server);
                total += t;
                rounds += 1;
                // noisy observation of each client's true per-batch time
                for ki in 0..k {
                    let obs = true_time(cpus[ki], &ref_profile, tiers[ki])
                        * (1.0 + rng.gen_f64(-0.1, 0.1));
                    prof.observe(ki, tiers[ki], obs, mbps[ki] * 1e6 / 8.0);
                }
            }
            let avg = total / rounds as f64;
            println!(
                "beta={beta:<4}  dynamic avg makespan {:>7.3}s | oracle {:>7.3}s (regret {:+5.1}%) | best static (tier {best_static}) {:>7.3}s ({:+5.1}%)",
                avg,
                t_oracle,
                100.0 * (avg - t_oracle) / t_oracle,
                t_static,
                100.0 * (avg - t_static) / t_static,
            );
        }
    }
    println!("\n(negative % vs static = dynamic wins; regret vs oracle should be small)");
    Ok(())
}
