//! Table 1 bench (fast estimator form): per-tier end-to-end round time for
//! 10 clients all pinned to the same tier, under both profile cases.
//!
//! Unlike `examples/table1.rs` (which trains to a target accuracy), this
//! bench runs TWO real rounds per (case, tier) cell and reports the
//! simulated round makespan decomposition — enough to regenerate the
//! table's *shape* (which tier wins per case) in seconds.
//!
//! Run: `cargo bench --bench table1_fixed_tiers`

use dtfl::harness::RunSpec;
use dtfl::simulation::ProfilePool;
use dtfl::util::bench::section;

fn main() -> dtfl::anyhow::Result<()> {
    let art = std::env::var("DTFL_BENCH_ARTIFACT").unwrap_or_else(|_| "tiny".into());
    let dataset = if art == "tiny" { "tiny" } else { "cifar10" };
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(&art);
    if !root.join("metadata.json").exists() {
        eprintln!("artifacts missing at {}; run `make artifacts` first", root.display());
        return Ok(());
    }

    // one shared runtime: artifacts compile once for the whole bench
    let rt = RunSpec { artifact: art.clone(), ..Default::default() }.open_runtime()?;
    for (case, pool) in [("case1", ProfilePool::Case1), ("case2", ProfilePool::Case2)] {
        section(&format!("Table 1 {case}: per-round makespan by fixed tier ({art})"));
        println!("tier    compute(s)  comm(s)   round makespan(s)");
        let mut best = (0usize, f64::INFINITY);
        for tier in 1..=7usize {
            let spec = RunSpec {
                artifact: art.clone(),
                dataset: dataset.into(),
                method: "static".into(),
                static_tier: Some(tier),
                pool,
                rounds: 2,
                eval_every: 100, // skip eval; timing only
                // full-ish local epochs so the z-upload vs model-transfer
                // tradeoff surfaces (the paper's Table 1 crossover)
                batch_cap: Some(8),
                ..Default::default()
            };
            let (_, records) = spec.run_shared(rt.clone())?;
            // second round avoids first-execution compile noise
            let r = records.last().unwrap();
            println!(
                "{:>4}  {:>10.2}  {:>8.2}  {:>14.2}",
                tier, r.makespan_compute, r.makespan_comm, r.makespan
            );
            if r.makespan < best.1 {
                best = (tier, r.makespan);
            }
        }
        // FedAvg row
        let spec = RunSpec {
            artifact: art.clone(),
            dataset: dataset.into(),
            method: "fedavg".into(),
            pool,
            rounds: 2,
            eval_every: 100,
            batch_cap: Some(8),
            ..Default::default()
        };
        let (_, records) = spec.run_shared(rt.clone())?;
        let r = records.last().unwrap();
        println!(
            "{:>4}  {:>10.2}  {:>8.2}  {:>14.2}",
            "FA", r.makespan_compute, r.makespan_comm, r.makespan
        );
        println!("--> best fixed tier for {case}: tier {} ({:.2}s/round)", best.0, best.1);
    }
    Ok(())
}
