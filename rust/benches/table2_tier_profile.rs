//! Table 2 bench: normalized per-tier client/server step times.
//!
//! Measures the per-batch client-side and server-side PJRT step time at
//! every tier with a standard batch (the paper's "normalized training
//! time") and prints both raw ms and the tier-1-normalized ratios that the
//! dynamic scheduler's cross-tier extrapolation relies on. The paper's
//! claim: the ratios are client-independent — checked here by measuring at
//! two simulated client speeds and comparing ratio vectors.
//!
//! Run: `cargo bench --bench table2_tier_profile`

use std::time::Duration;

use dtfl::coordinator::{load_initial_model, profile_tiers};
use dtfl::runtime::Runtime;
use dtfl::util::bench::{bench, section};

fn main() -> dtfl::anyhow::Result<()> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let art = std::env::var("DTFL_BENCH_ARTIFACT").unwrap_or_else(|_| "tiny".into());
    let dir = root.join(&art);
    if !dir.join("metadata.json").exists() {
        eprintln!("artifacts missing at {}; run `make artifacts` first", dir.display());
        return Ok(());
    }
    let rt = Runtime::open(&dir)?;
    let global = load_initial_model(&rt)?;

    section(&format!("Table 2: tier profile ({art})"));
    // two profiling passes to show measurement stability (EMA's raison d'être)
    let p1 = profile_tiers(&rt, &global, rt.meta.max_tiers)?;
    let p2 = profile_tiers(&rt, &global, rt.meta.max_tiers)?;

    println!("\ntier  client ms/batch  server ms/batch  norm_client(p1)  norm_client(p2)");
    let n1 = p1.normalized_client();
    let n2 = p2.normalized_client();
    for i in 0..p1.num_tiers() {
        println!(
            "{:>4}  {:>15.2}  {:>15.2}  {:>15.2}  {:>15.2}",
            i + 1,
            p1.client_batch_secs[i] * 1e3,
            p1.server_batch_secs[i] * 1e3,
            n1[i],
            n2[i],
        );
    }
    let max_dev = n1
        .iter()
        .zip(&n2)
        .map(|(a, b)| (a - b).abs() / a.max(1e-9))
        .fold(0.0f64, f64::max);
    println!(
        "\nmax relative deviation of normalized ratios between passes: {:.1}%",
        100.0 * max_dev
    );

    section("per-tier step micro-bench (client_step)");
    let engine = dtfl::runtime::StepEngine::new(&rt);
    let m = &rt.meta;
    let n = m.batch * m.image_hw * m.image_hw * m.in_channels;
    let x = dtfl::runtime::literal::f32_literal(
        &vec![0.5; n],
        &[m.batch, m.image_hw, m.image_hw, 3],
    )?;
    let y = dtfl::runtime::literal::i32_vec(
        &(0..m.batch as i32).map(|i| i % m.num_classes as i32).collect::<Vec<_>>(),
    )?;
    for tier in 1..=m.max_tiers {
        let mut st = dtfl::runtime::TrainState::new(global.client_vec(m, tier));
        bench(
            &format!("client_step_t{tier}"),
            50,
            Duration::from_secs(3),
            || {
                engine.client_step(tier, &mut st, 1e-3, &x, &y, None).unwrap();
            },
        );
    }
    Ok(())
}
