//! L3 hot-path micro-benchmarks (the §Perf targets):
//!   * blocked vs naive matmul kernels (GFLOP/s) + scratch-arena peak bytes
//!   * fused vs unfused forward path (gn/relu epilogues, 1×1 im2col
//!     elision) + the `kernels::tune` lane-width × MR/NR register-tile
//!     sweep
//!   * per-level SIMD dispatch throughput (scalar/AVX2/AVX-512/NEON)
//!   * flat-layout aggregation (O(K·P) FMAs — the per-round CPU hot loop)
//!   * dynamic tier scheduling (O(K·M) estimates)
//!   * literal construction / extraction (backend boundary per step)
//!   * batch assembly, patch shuffling, dataset generation
//!   * `bench_round`: whole-round throughput, sequential (1 thread) vs the
//!     parallel round engine (all cores), K=50 clients
//!
//! Run: `cargo bench --bench micro_hotpath`
//!
//! `cargo bench --bench micro_hotpath -- fused` runs only the fused-path
//! section (CI uses it as a release-codegen smoke for the fused kernels);
//! in that mode `BENCH_hotpath.json` is left untouched so a partial run
//! never clobbers full-run numbers.
//!
//! Emits `BENCH_hotpath.json` at the repository root so the perf trajectory
//! is tracked across PRs.

use std::time::Duration;

use dtfl::coordinator::{
    aggregate, schedule, ClientLoad, ClientUpdate, GlobalModel, Profiler, TierProfile,
};
use dtfl::data::{generate_train, patch_shuffle, Batcher, DatasetSpec};
use dtfl::harness::{
    kernels_to_json, measure_async_throughput, measure_fleet_scale, measure_fused_throughput,
    measure_kernel_throughput, measure_pipeline_throughput, measure_robustness_throughput,
    measure_round_throughput, measure_scenario_throughput, measure_simd_throughput,
    measure_wire_efficiency,
};
use dtfl::runtime::kernels::tune;
use dtfl::runtime::{literal as lit, Metadata};
use dtfl::simulation::ServerModel;
use dtfl::util::bench::{bench, hotpath_report_path, section, BenchReport};
use dtfl::util::Rng64;

fn tiny_meta() -> Metadata {
    // `tiny` is a built-in config: Metadata::load synthesizes it even with
    // no artifacts on disk
    let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    Metadata::load(&d).expect("tiny is a built-in config")
}

/// Pipelined-vs-barrier round throughput + sharded-aggregation GB/s
/// (shared probe in `harness::measure_pipeline_throughput`).
fn bench_pipeline(report: &mut BenchReport, clients: usize, rounds: usize) {
    section(&format!("bench_pipeline: K={clients} barrier vs pipelined engine"));
    let pt = measure_pipeline_throughput(clients, rounds, 16).expect("pipeline probe");
    assert!(pt.bit_identical, "pipelined engine must be bit-identical to the barrier engine");
    println!(
        "K={clients}: barrier {:.3}s/round, pipelined {:.3}s/round — {:.2}x",
        pt.barrier_secs_per_round,
        pt.pipelined_secs_per_round,
        pt.speedup()
    );
    for s in &pt.agg_shards {
        println!(
            "agg fold K={} P={} shards={:<3} {:>7.2} GB/s",
            s.clients, s.params, s.shards, s.gb_per_sec
        );
    }
    report.extra("pipeline", pt.to_json("cargo bench micro_hotpath"));
}

/// Fused vs unfused forward path (shared probe in
/// `harness::measure_fused_throughput`) plus the MR/NR register-tile sweep.
/// Returns the `fused` JSON object so the filtered `-- fused` smoke can
/// print without writing the report.
fn bench_fused(clients: usize, rounds: usize) -> dtfl::util::json::Json {
    section(&format!("bench_fused: K={clients} fused vs unfused forward path"));
    let ft = measure_fused_throughput(clients, rounds, 16).expect("fused probe");
    assert!(ft.bit_identical, "fused forward path must be bit-identical to unfused");
    println!(
        "K={clients}: unfused {:.3}s/round, fused {:.3}s/round — {:.2}x",
        ft.unfused_secs_per_round,
        ft.fused_secs_per_round,
        ft.round_speedup()
    );
    println!(
        "full fwd+bwd step: unfused {:.2} GFLOP/s, fused {:.2} GFLOP/s — {:.2}x; \
         arena peak {} → {} bytes",
        ft.step_gflops_unfused,
        ft.step_gflops_fused,
        ft.step_speedup(),
        ft.arena_peak_unfused,
        ft.arena_peak_fused
    );
    println!(
        "1×1 elision rows={} {}→{}: {:.2} GB/s ({:.2}x vs im2col)",
        ft.elision.rows,
        ft.elision.cin,
        ft.elision.cout,
        ft.elision.gb_per_sec,
        ft.elision.im2col_secs / ft.elision.elided_secs.max(1e-12)
    );

    section("kernels::tune — lane-width × MR/NR register-tile sweep (conv hot shape)");
    let sweep = tune::sweep(512, 144, 64, Duration::from_millis(400));
    for s in &sweep {
        println!(
            "tile {}x{:<2} {:<7} {:>7.2} GFLOP/s{}",
            s.mr,
            s.nr,
            s.simd,
            s.gflops,
            if s.pinned { "  <- pinned in source" } else { "" }
        );
    }
    ft.to_json(&sweep, "cargo bench micro_hotpath")
}

/// Per-level SIMD dispatch probe: packed-matmul GFLOP/s and L1-resident
/// agg-fold GB/s at every available level, bit-identity asserted (shared
/// probe in `harness::measure_simd_throughput`).
fn bench_simd(report: &mut BenchReport) {
    section("simd dispatch: per-level matmul GFLOP/s + L1-resident agg GB/s");
    let sd = measure_simd_throughput(Duration::from_millis(400)).expect("simd probe");
    assert!(sd.bit_identical, "every dispatch level must match scalar bits");
    for s in &sd.levels {
        println!(
            "{:<7} matmul {:>7.2} GFLOP/s   agg {:>7.2} GB/s{}",
            s.level,
            s.matmul_gflops,
            s.agg_gb_per_sec,
            if s.level == sd.active { "  <- active" } else { "" }
        );
    }
    println!(
        "best vs scalar: matmul {:.2}x, agg {:.2}x ({:.2} GB/s L1-resident)",
        sd.matmul_speedup_vs_scalar(),
        sd.agg_speedup_vs_scalar(),
        sd.agg_best_gb_per_sec()
    );
    report.extra("simd", sd.to_json("cargo bench micro_hotpath"));
}

/// Scenario probe: flash-crowd DTFL makespan + delta-vs-full broadcast
/// bytes (shared probe in `harness::measure_scenario_throughput`).
fn bench_scenario(report: &mut BenchReport, rounds: usize) {
    section("bench_scenario: flash-crowd fleet, delta vs full broadcast");
    let st = measure_scenario_throughput(rounds).expect("scenario probe");
    assert!(
        st.bit_identical,
        "delta-compressed downlink must not change FedAvg parameter bits"
    );
    println!(
        "{}: K={} DTFL sim {:.1}s over {} rounds ({:.2}s mean makespan, {} straggles, {} bytes)",
        st.name,
        st.clients,
        st.dtfl_sim_secs,
        st.rounds,
        st.dtfl_mean_makespan,
        st.dtfl_straggles,
        st.dtfl_wire_bytes
    );
    println!(
        "broadcast bytes: delta {} vs full {} — {:.1}% saved",
        st.fedavg_delta_bytes,
        st.fedavg_full_bytes,
        100.0 * st.bytes_saved_ratio()
    );
    report.extra("scenario", st.to_json("cargo bench micro_hotpath"));
}

/// Robustness probe: robust-fold bandwidth vs the plain sharded mean, plus
/// the committed `scenarios/byzantine_flaky.toml` run under a plain vs a
/// trimmed-mean fold (shared probe in
/// `harness::measure_robustness_throughput`).
fn bench_robustness(report: &mut BenchReport, clients: usize, rounds: usize) {
    section(&format!("bench_robustness: K={clients} robust folds + byzantine-flaky scenario"));
    let rb = measure_robustness_throughput(clients, rounds, Duration::from_millis(400))
        .expect("robustness probe");
    println!(
        "fold K={} P={}: plain {:.2} GB/s, trimmed-mean {:.2} GB/s, median {:.2} GB/s",
        rb.clients, rb.params, rb.plain_gb_per_sec, rb.trimmed_gb_per_sec, rb.median_gb_per_sec
    );
    println!(
        "{}: K={} sim {:.1}s over {} rounds ({:.2}s mean makespan, {} quarantined, {} retries)",
        rb.scenario, rb.scenario_clients, rb.sim_secs, rb.rounds, rb.mean_makespan_secs,
        rb.quarantined, rb.retries
    );
    println!(
        "final train loss: mean fold {:.4} vs trimmed fold {:.4}",
        rb.mean_final_train_loss, rb.trimmed_final_train_loss
    );
    report.extra("robustness", rb.to_json("cargo bench micro_hotpath"));
}

/// Async tier-engine probe: event-queue throughput plus the sync-vs-async
/// makespan pin on the committed straggler-heavy scenario (shared probe in
/// `harness::measure_async_throughput`).
fn bench_async_tiers(report: &mut BenchReport, rounds: usize) {
    section("bench_async_tiers: straggler-heavy fleet, event queue vs sync barrier");
    let at = measure_async_throughput(rounds).expect("async tiers probe");
    assert!(at.bit_identical, "async event trace must be knob-invariant");
    println!(
        "{}: K={} async {:.2}s vs drop {:.2}s / wait {:.2}s — {:.2}x / {:.2}x",
        at.name,
        at.clients,
        at.async_sim_secs,
        at.drop_sim_secs,
        at.wait_sim_secs,
        at.speedup_vs_drop(),
        at.speedup_vs_wait()
    );
    println!(
        "{} events over {} windows ({:.0} events/s); final test loss async {:.4} vs drop {:.4}",
        at.events,
        at.rounds,
        at.events_per_sec,
        at.async_final_test_loss,
        at.drop_final_test_loss
    );
    report.extra("async_tiers", at.to_json("cargo bench micro_hotpath"));
}

/// Uplink-codec probe: per-codec uplink bytes plus the final loss on the
/// committed straggler-heavy scenario (shared probe in
/// `harness::measure_wire_efficiency`).
fn bench_wire_efficiency(report: &mut BenchReport, rounds: usize) {
    section("bench_wire_efficiency: uplink codecs on the straggler-heavy fleet");
    let we = measure_wire_efficiency(rounds).expect("wire efficiency probe");
    assert!(we.bit_identical, "lossless uplink delta must match the raw leg bit-for-bit");
    assert!(
        we.delta_up_bytes < we.raw_up_bytes,
        "uplink delta must save bytes ({} vs {})",
        we.delta_up_bytes,
        we.raw_up_bytes
    );
    println!(
        "{}: K={} up-bytes raw {} / delta {} ({:.1}% saved) / int8 {} / topk {}",
        we.name,
        we.clients,
        we.raw_up_bytes,
        we.delta_up_bytes,
        100.0 * we.delta_saved_ratio(),
        we.int8_up_bytes,
        we.topk_up_bytes
    );
    println!(
        "final train loss: raw {:.4} / delta {:.4} / int8 {:.4} / topk {:.4}",
        we.raw_final_loss, we.delta_final_loss, we.int8_final_loss, we.topk_final_loss
    );
    report.extra("wire_efficiency", we.to_json("cargo bench micro_hotpath"));
}

/// Fleet-scale probe: the mega-fleet scenario shape at three fleet sizes
/// under the cohort-vectorized engine, fixed participant count (shared
/// probe in `harness::measure_fleet_scale`).
fn bench_fleet_scale(report: &mut BenchReport, rounds: usize) {
    section("bench_fleet_scale: cohort-vectorized fleet, K = 50 / 10^4 / 10^6");
    let fs = measure_fleet_scale(&[50, 10_000, 1_000_000], rounds).expect("fleet scale probe");
    for l in &fs.legs {
        assert!(
            l.resident_bytes > 0 && l.resident_bytes <= l.resident_bound_bytes,
            "fleet {}: snapshot residency {} outside (0, {}]",
            l.fleet,
            l.resident_bytes,
            l.resident_bound_bytes
        );
        println!(
            "fleet {:>9}: {} participants/round, makespan {:.3}s, coordinator {:.4}s/round, \
             resident {} / bound {} bytes, {} cohort advances",
            l.fleet,
            l.participants,
            l.mean_makespan_secs,
            l.coordinator_secs_per_round,
            l.resident_bytes,
            l.resident_bound_bytes,
            l.cohort_advances
        );
    }
    report.extra("fleet_scale", fs.to_json("cargo bench micro_hotpath"));
}

/// Round-throughput comparison: K clients, 1 thread vs all cores (shared
/// probe in `harness::measure_round_throughput`).
fn bench_round(report: &mut BenchReport, clients: usize, rounds: usize) {
    section(&format!("bench_round: K={clients} sequential vs parallel"));
    let rt = measure_round_throughput(clients, rounds, 16).expect("round throughput probe");
    assert!(rt.bit_identical, "parallel round engine must be bit-identical to sequential");
    println!(
        "K={clients}: sequential {:.3}s/round, parallel({} threads) {:.3}s/round — {:.2}x",
        rt.seq_secs_per_round,
        rt.threads,
        rt.par_secs_per_round,
        rt.speedup()
    );
    report.extra("bench_round", rt.to_json("cargo bench micro_hotpath"));
}

fn main() {
    // `cargo bench --bench micro_hotpath -- fused`: release-codegen smoke
    // for the fused kernels only; skips the report write so a partial run
    // never clobbers the full numbers
    if std::env::args().skip(1).any(|a| a == "fused") {
        bench_fused(50, 1);
        return;
    }

    let budget = Duration::from_secs(3);
    let mut report = BenchReport::new();

    // ---------------- matmul kernels ----------------
    {
        section("matmul kernels: blocked vs naive (GFLOP/s), arena peak");
        let (kernels, arena_peak) =
            measure_kernel_throughput(Duration::from_millis(800)).expect("kernel probe");
        for kt in &kernels {
            println!(
                "{:<10} {:>4}x{:<4}x{:<4}  blocked {:>7.2} GFLOP/s  naive {:>7.2} GFLOP/s  {:.2}x",
                kt.name, kt.m, kt.k, kt.n, kt.gflops_blocked, kt.gflops_naive, kt.speedup()
            );
        }
        println!("arena peak: {arena_peak} bytes");
        report.extra(
            "kernels",
            kernels_to_json(&kernels, arena_peak, "cargo bench micro_hotpath"),
        );
    }

    // ---------------- aggregation ----------------
    {
        let meta = tiny_meta();
        section("aggregation (step ⑤): K clients × P params");
        let prev = GlobalModel::new(
            vec![0.1; meta.total_params],
            meta.tiers.iter().map(|t| vec![0.1; t.aux_len]).collect(),
            &meta,
        );
        for k in [10usize, 50, 200] {
            let updates: Vec<ClientUpdate> = (0..k)
                .map(|i| {
                    let tier = 1 + i % meta.max_tiers;
                    let t = meta.tier(tier);
                    ClientUpdate {
                        client_id: i,
                        tier,
                        weight: 100.0,
                        client_vec: vec![0.5; t.client_vec_len],
                        server_vec: vec![0.5; t.server_vec_len],
                    }
                })
                .collect();
            report.push(bench(
                &format!("aggregate K={k} P={}", meta.total_params),
                200,
                budget,
                || {
                    let g = aggregate(&meta, &prev, &updates).unwrap();
                    std::hint::black_box(g.flat[0]);
                },
            ));
        }

        // ---------------- scheduler ----------------
        section("dynamic tier scheduler (Algorithm 1, lines 21–35)");
        let profile = TierProfile {
            client_batch_secs: (0..meta.max_tiers).map(|i| 0.1 + 0.05 * i as f64).collect(),
            server_batch_secs: (0..meta.max_tiers).map(|i| 0.4 - 0.05 * i as f64).collect(),
        };
        for k in [10usize, 200, 2000] {
            let mut prof = Profiler::new(profile.clone(), k, 0.5);
            let mut rng = Rng64::seed_from_u64(1);
            for i in 0..k {
                prof.observe(i, 1 + i % meta.max_tiers, rng.gen_f64(0.01, 2.0), 1e6);
            }
            let loads = vec![ClientLoad { n_batches: 4, participating: true }; k];
            let server = ServerModel::default();
            report.push(bench(
                &format!("schedule K={k} M={}", meta.max_tiers),
                500,
                budget,
                || {
                    let s = schedule(&meta, &prof, &server, &loads, meta.max_tiers);
                    std::hint::black_box(s.t_max);
                },
            ));
        }
    }

    // ---------------- literal conversions ----------------
    section("literal conversions (backend boundary, per step)");
    for n in [44_370usize, 400_000] {
        let data = vec![0.5f32; n];
        report.push(bench(&format!("f32_vec -> literal n={n}"), 500, budget, || {
            let l = lit::f32_vec(&data).unwrap();
            std::hint::black_box(l.element_count());
        }));
        let l = lit::f32_vec(&data).unwrap();
        let mut dst = vec![0.0f32; n];
        report.push(bench(&format!("literal -> buffer  n={n}"), 500, budget, || {
            lit::copy_to_f32(&l, &mut dst).unwrap();
            std::hint::black_box(dst[0]);
        }));
    }

    // ---------------- data pipeline ----------------
    section("data pipeline");
    let spec = DatasetSpec::tiny(512, 64);
    report.push(bench("generate_train 512x16x16x3", 20, budget, || {
        let d = generate_train(&spec);
        std::hint::black_box(d.images.len());
    }));
    let ds = generate_train(&spec);
    let idx: Vec<usize> = (0..64).collect();
    let b = Batcher::new(&ds, &idx, 8);
    report.push(bench("batch assembly 8x16x16x3", 2000, budget, || {
        let bt = b.batch(0).unwrap();
        std::hint::black_box(bt.size);
    }));
    let mut z = vec![0.5f32; 8 * 16 * 16 * 8];
    report.push(bench("patch_shuffle 8x16x16x8 p=4", 2000, budget, || {
        patch_shuffle(&mut z, &[8, 16, 16, 8], 4, 9);
        std::hint::black_box(z[0]);
    }));

    // ---------------- whole-round throughput ----------------
    bench_round(&mut report, 50, 2);

    // ---------------- pipelined engine + sharded aggregation ----------------
    bench_pipeline(&mut report, 50, 2);

    // ---------------- fused forward path + NR sweep ----------------
    let fused = bench_fused(50, 2);
    report.extra("fused", fused);

    // ---------------- SIMD dispatch levels ----------------
    bench_simd(&mut report);

    // ---------------- scenario engine + delta downlink ----------------
    bench_scenario(&mut report, 8);

    // ---------------- fault injection + robust aggregation ----------------
    bench_robustness(&mut report, 50, 6);

    // ---------------- async tier engine + event queue ----------------
    bench_async_tiers(&mut report, 8);

    // ---------------- uplink codec family + wire accounting ----------------
    bench_wire_efficiency(&mut report, 6);

    // ---------------- fleet scale (cohort-vectorized engine) ----------------
    bench_fleet_scale(&mut report, 3);

    report.write(hotpath_report_path()).expect("write BENCH_hotpath.json");
}
